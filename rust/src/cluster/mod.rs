//! The simulated testbed: hosts + NetFPGA cards + cables, driven by the
//! discrete-event loop.
//!
//! This is where the cost model gets charged: host-stack costs on the
//! software path, crossing costs on the offload path, wire serialization
//! per frame, NIC pipeline + line-rate combine cycles inside the cards.
//! The benchmark driver loops back-to-back MPI_Scan calls per rank (the
//! paper's modified OSU micro-benchmark), records host-observed latency,
//! and — on the offload path — the NIC's own offload->release timestamps
//! (Figs. 6/7).

pub mod session;

pub use session::Session;

use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::config::{ExpConfig, FabricConfig, WorkloadSpec};
use crate::data::{Dtype, Op, Payload};
use crate::fpga::engine::EngineOpts;
use crate::fpga::{make_engine, EngineCtx, HpuJob, Nic, NicAction, PendingTx};
use crate::metrics::{Attribution, RunMetrics};
use crate::mpi::{make_sw, SwAction, SwCtx, SwScanAlgo};
use crate::net::{
    frame::fragment, BgMsg, FaultPlan, Frame, FrameBody, LinkFault, PortNo, Probe, Rank, RelAck,
    RouteTable, SwMsg, Topology,
};
use crate::offload::{build_request, node_role};
use crate::packet::{CollPacket, MsgType};
use crate::runtime::{engine::oracle_prefix, Compute};
use crate::sim::{EventKind, EventQueue, HostMsg, OffloadRequest, SimTime, SplitMix64, EVENT_KINDS};
use crate::trace::{SpanData, TraceKind};

/// Per-rank host process: the OSU-style benchmark driver plus (software
/// path) the per-epoch algorithm instances and their unexpected-message
/// reassembly.
struct Host {
    iter: u32,
    total_iters: u32,
    call_time: SimTime,
    in_flight: bool,
    sw: HashMap<u32, Box<dyn SwScanAlgo>>,
    sw_reasm: crate::fpga::reassembly::Reassembler<(Rank, u16, u16, u32)>,
    done: bool,
}

/// One tenant: a contiguous communicator of `size` global ranks starting
/// at `base`, running its own collective stream described by `cfg` (a
/// fully composed per-tenant view — fabric fields shared, workload
/// fields the tenant's own).
struct Tenant {
    comm: u16,
    base: usize,
    size: usize,
    cfg: ExpConfig,
}

/// One background point-to-point flow: seeded (src, dst) pair injecting
/// `remaining` more frames, self-clocked every `cfg.bg_gap_ns`.
struct BgFlow {
    src: Rank,
    dst: Rank,
    remaining: u64,
    seq: u32,
}

/// Raw latency-attribution accumulators (only built when the run has
/// `attribution = true`).  Components are charged as events fire,
/// gated on the charged rank being inside a measured (post-warmup)
/// iteration; [`Cluster::run`] folds them into an [`Attribution`]
/// whose parts sum exactly to the pooled measured host latency.
struct AttrState {
    /// Per-rank "inside a measured iteration" flag.
    measuring: Vec<bool>,
    /// Pooled measured host latency (the breakdown's exact total).
    total: u64,
    wire: u64,
    switch_queue: u64,
    hpu_queue: u64,
    handler_exec: u64,
    compute: u64,
    recovery: u64,
}

impl AttrState {
    fn new(p: usize) -> AttrState {
        AttrState {
            measuring: vec![false; p],
            total: 0,
            wire: 0,
            switch_queue: 0,
            hpu_queue: 0,
            handler_exec: 0,
            compute: 0,
            recovery: 0,
        }
    }
}

/// Event-loop self-profile (`nfscan run --profile`): per-`EventKind`
/// pop counts, handler wall-clock, and allocation events (the latter
/// non-zero only when the counting allocator is installed).  Purely
/// observational — wall-clock is host noise and never feeds back into
/// sim time or artifacts.
#[derive(Clone, Debug, Default)]
pub struct LoopProfile {
    /// Total events popped.
    pub pops: u64,
    /// Pops by [`EventKind::index`] slot.
    pub counts: [u64; EVENT_KINDS],
    /// Host wall-clock spent in each kind's handler, nanoseconds.
    pub wall_ns: [u64; EVENT_KINDS],
    /// Allocation events inside each kind's handler.
    pub allocs: [u64; EVENT_KINDS],
}

impl LoopProfile {
    /// Fixed-width table: one row per event kind plus a total row.
    pub fn render(&self) -> String {
        let mut t = crate::metrics::Table::new(&["event", "pops", "wall_us", "allocs"]);
        for i in 0..EVENT_KINDS {
            t.row(vec![
                crate::sim::EVENT_KIND_NAMES[i].to_string(),
                self.counts[i].to_string(),
                format!("{:.1}", self.wall_ns[i] as f64 / 1e3),
                self.allocs[i].to_string(),
            ]);
        }
        t.row(vec![
            "total".into(),
            self.pops.to_string(),
            format!("{:.1}", self.wall_ns.iter().sum::<u64>() as f64 / 1e3),
            self.allocs.iter().sum::<u64>().to_string(),
        ]);
        t.render()
    }
}

/// Epoch carried by a frame's body (0 for background and ack frames).
fn frame_epoch(frame: &Frame) -> u16 {
    match &frame.body {
        FrameBody::Coll(pkt) => pkt.epoch(),
        FrameBody::Sw(m) => (m.epoch & 0xFFFF) as u16,
        _ => 0,
    }
}

/// How long a reorder fault parks a frame past its normal arrival: long
/// enough that a back-to-back successor frame on the same link lands
/// first (one switch forwarding delay plus slack), short enough that the
/// retransmit timer does not fire spuriously.
const REORDER_HOLD_NS: u64 = 2_000;

pub struct Cluster {
    pub cfg: ExpConfig,
    topo: Topology,
    routes: RouteTable,
    q: EventQueue,
    hosts: Vec<Host>,
    nics: Vec<Nic>,
    compute: Rc<dyn Compute>,
    pub metrics: RunMetrics,
    /// The tenant table; `rank_tenant[r]` indexes into it.
    tenants: Vec<Tenant>,
    rank_tenant: Vec<usize>,
    bg: Vec<BgFlow>,
    /// Per-(communicator, epoch) contributions for the verify path,
    /// communicator-locally indexed.
    contributions: HashMap<(u16, u32), Vec<Option<Payload>>>,
    verified_counts: HashMap<(u16, u32), usize>,
    master_rng: SplitMix64,
    /// The hostile-network fault model: seeded random loss, scheduled
    /// drops, trunk degradation.  Quiet (`!lossy()`) by default, in which
    /// case the reliability layer below never arms and the event
    /// schedule is byte-identical to a fault-free build.
    fault: FaultPlan,
    /// Next reliable transaction id (0 is reserved for "unreliable").
    next_txn: u64,
    /// Fail-stop state, indexed by graph node (ranks then switches).
    /// Dead nodes emit, forward and accept nothing; set by scheduled
    /// crashes and by suspicion-driven exclusion.
    dead: Vec<bool>,
    /// Per-rank "the survivors have declared this rank dead" flag —
    /// suspicion dedup (a rank is excluded at most once).
    dead_declared: Vec<bool>,
    /// When each crashed node actually died (detection-latency metric;
    /// a suspect absent here is a false suspicion).
    crash_times: HashMap<usize, SimTime>,
    /// Tenants whose group has shrunk: the in-flight epoch completed
    /// over the survivor communicator and the stream stops.
    degraded_tenants: Vec<bool>,
    /// (comm, epoch) pairs completed via shrunk-group degradation —
    /// their results come from the survivor oracle, so the in-run
    /// verifier must not compare them against the full-group one.
    degraded: HashSet<(u16, u32)>,
    /// Last completion timestamp: the progress the watchdog watches.
    last_progress: SimTime,
    /// Set when a card exhausts its retransmit budget: the run stops and
    /// surfaces this instead of deadlocking.
    fatal: Option<String>,
    /// Application mode: caller-provided contributions for iteration 0
    /// (see [`Cluster::scan_once`]) and the per-rank results collected.
    /// Crate-visible so the crash property tests can inject known data
    /// and read survivor slots without the all-ranks-completed check
    /// [`Session::scan_once`] applies.
    pub(crate) injected: Option<Vec<Payload>>,
    pub results: Vec<Option<Payload>>,
    /// Milestone trace (disabled by default; `enable_trace` turns it on).
    pub trace: crate::trace::Trace,
    /// Latency-attribution accumulators (`cfg.attribution` runs only).
    attr: Option<Box<AttrState>>,
    /// Event-loop self-profile (`enable_profile` turns it on).
    profile: Option<Box<LoopProfile>>,
}

impl Cluster {
    /// Homogeneous construction: `cfg.tenants` identical communicators
    /// splitting `cfg.p` contiguously (the flat-config entry point every
    /// sweep and bench uses).
    pub fn new(cfg: ExpConfig, compute: Rc<dyn Compute>) -> Cluster {
        cfg.validate().expect("invalid experiment config");
        let g = cfg.group_size();
        let tenants = (0..cfg.tenants)
            .map(|t| Tenant { comm: t as u16, base: t * g, size: g, cfg: cfg.clone() })
            .collect();
        Self::build(cfg, tenants, compute)
    }

    /// Heterogeneous construction: each `(size, spec)` entry is one
    /// tenant over the next `size` global ranks, with its own collective,
    /// algorithm, path and message size.  Sizes must sum to `fabric.p`.
    /// The [`Session`] builder is the ergonomic front for this.
    pub fn with_tenants(
        fabric: &FabricConfig,
        specs: &[(usize, WorkloadSpec)],
        compute: Rc<dyn Compute>,
    ) -> Result<Cluster> {
        if specs.is_empty() {
            bail!("at least one tenant required");
        }
        let total: usize = specs.iter().map(|(n, _)| n).sum();
        if total != fabric.p {
            bail!("tenant sizes sum to {total}, fabric has p = {}", fabric.p);
        }
        if fabric.bg_flows > 0 && fabric.bg_gap_ns == 0 {
            bail!("bg_gap_ns must be > 0 when background flows are on");
        }
        let mut tenants = Vec::with_capacity(specs.len());
        let mut base = 0;
        for (i, (size, spec)) in specs.iter().enumerate() {
            // validate each workload against the group it actually runs
            // over (algorithm/collective rank constraints are per tenant,
            // not per fabric)
            let mut probe = ExpConfig::compose(fabric, spec);
            probe.p = *size;
            probe.topology = "auto".into();
            probe.validate().map_err(|e| anyhow!("tenant {i}: {e}"))?;
            let cfg = ExpConfig::compose(fabric, spec);
            tenants.push(Tenant { comm: i as u16, base, size: *size, cfg });
            base += *size;
        }
        // the shared wiring must build at full scale
        let mut fcfg = ExpConfig::compose(fabric, &specs[0].1);
        fcfg.tenants = specs.len();
        Topology::build(fcfg.topology_spec(), fabric.p)
            .map_err(|e| anyhow!("topology: {e}"))?;
        Ok(Self::build(fcfg, tenants, compute))
    }

    /// Shared constructor body.  `cfg` carries the fabric-level knobs
    /// (wiring, cost model, seed, background traffic); per-tenant reads
    /// go through the tenant table.
    fn build(cfg: ExpConfig, tenants: Vec<Tenant>, compute: Rc<dyn Compute>) -> Cluster {
        let topo = cfg.resolve_topology();
        let routes = RouteTable::build(&topo);
        let p = cfg.p;
        let mut rank_tenant = vec![usize::MAX; p];
        for (ti, t) in tenants.iter().enumerate() {
            for r in t.base..t.base + t.size {
                rank_tenant[r] = ti;
            }
        }
        assert!(rank_tenant.iter().all(|&ti| ti != usize::MAX), "tenants must cover all ranks");
        Cluster {
            master_rng: SplitMix64::new(cfg.seed),
            fault: cfg.fault_plan(),
            next_txn: 1,
            dead: vec![false; topo.nodes()],
            dead_declared: vec![false; p],
            crash_times: HashMap::new(),
            degraded_tenants: vec![false; tenants.len()],
            degraded: HashSet::new(),
            last_progress: SimTime::ZERO,
            fatal: None,
            hosts: (0..p)
                .map(|r| {
                    let tcfg = &tenants[rank_tenant[r]].cfg;
                    Host {
                        iter: 0,
                        total_iters: (tcfg.warmup + tcfg.iters) as u32,
                        call_time: SimTime::ZERO,
                        in_flight: false,
                        sw: HashMap::with_capacity(4),
                        sw_reasm: crate::fpga::reassembly::Reassembler::new(64),
                        done: false,
                    }
                })
                .collect(),
            // one NIC per graph node: rank NICs first, then the switches
            // of the hierarchical topologies (forward-only).  Only rank
            // NICs own handler units; switches never run activations.
            nics: (0..topo.nodes())
                .map(|n| {
                    let mut nic = Nic::new(n, topo.ports_of(n).max(1));
                    if n < p {
                        nic.hpu.units = cfg.cost.hpus;
                    }
                    nic
                })
                .collect(),
            compute,
            metrics: RunMetrics::with_tenants(p, tenants.len()),
            rank_tenant,
            bg: Vec::new(),
            // a handful of epochs are ever in flight at once (flow
            // control bounds pipelining) — presize for that steady state
            contributions: HashMap::with_capacity(if cfg.verify { 8 } else { 0 }),
            verified_counts: HashMap::with_capacity(if cfg.verify { 8 } else { 0 }),
            q: EventQueue::new(),
            injected: None,
            results: vec![None; p],
            trace: crate::trace::Trace::disabled(),
            attr: if cfg.attribution { Some(Box::new(AttrState::new(p))) } else { None },
            profile: None,
            topo,
            routes,
            cfg,
            tenants,
        }
    }

    /// Record the last `cap` milestones (host calls, offloads, results,
    /// completions) for `Trace::timeline` rendering.
    pub fn enable_trace(&mut self, cap: usize) {
        self.trace = crate::trace::Trace::new(cap, true);
    }

    /// Turn on the event-loop self-profile (per-kind pop counts, host
    /// wall-clock, allocation events).  Purely observational: sim time
    /// and artifact bytes are unaffected.
    pub fn enable_profile(&mut self) {
        self.profile = Some(Box::default());
    }

    pub fn profile(&self) -> Option<&LoopProfile> {
        self.profile.as_deref()
    }

    /// True when `rank` is a host rank currently inside a measured
    /// (post-warmup) iteration of an attribution run.
    fn attr_measuring(&self, rank: Rank) -> bool {
        match &self.attr {
            Some(a) => rank < self.cfg.p && a.measuring[rank],
            None => false,
        }
    }

    /// Charge attribution components for `rank` if it is measuring.
    fn attr_charge(&mut self, rank: Rank, f: impl FnOnce(&mut AttrState)) {
        if self.attr_measuring(rank) {
            f(self.attr.as_deref_mut().expect("measuring implies attribution"));
        }
    }

    /// Application entry point: run ONE collective over caller-provided
    /// per-rank contributions and return each rank's result.  This is the
    /// MPI_Scan/MPI_Exscan a real program would call — the OSU loop is
    /// just this, repeated.
    pub fn scan_once(
        cfg: ExpConfig,
        compute: Rc<dyn Compute>,
        contributions: Vec<Payload>,
    ) -> Result<(Vec<Payload>, RunMetrics)> {
        assert_eq!(contributions.len(), cfg.p, "one contribution per rank");
        assert!(
            contributions.iter().all(|c| c.dtype() == cfg.dtype),
            "contribution dtype must match config"
        );
        // thin wrapper over the Session builder: one tenant per
        // homogeneous group, all running the same workload
        let g = cfg.group_size();
        let w = cfg.workload();
        let mut s = Session::on_fabric(cfg.fabric()).compute(compute);
        for _ in 0..cfg.tenants {
            s = s.tenant(g, w.clone());
        }
        s.scan_once(contributions)
    }

    /// Deterministic per-(rank, epoch) contribution, kept well-conditioned
    /// for the configured op (so verification compares exact/stable
    /// values).  MPI_Barrier carries no data.  Public because the
    /// handler-conformance CLI (`nfscan values`) feeds the exact same
    /// data through different offload paths and byte-compares results.
    pub fn gen_payload(cfg: &ExpConfig, rank: Rank, epoch: u32) -> Payload {
        let mut rng =
            SplitMix64::new(cfg.seed ^ ((rank as u64) << 40) ^ ((epoch as u64) << 8) ^ 0x9E37);
        let n = if cfg.coll == crate::packet::CollType::Barrier { 0 } else { cfg.msg_elems() };
        match cfg.dtype {
            Dtype::I32 => {
                let vals: Vec<i32> =
                    (0..n).map(|_| rng.range_i64(-9, 9) as i32).collect();
                Payload::from_i32(&vals)
            }
            Dtype::F32 => {
                let vals: Vec<f32> = (0..n)
                    .map(|_| {
                        if cfg.op == Op::Prod {
                            0.9 + 0.2 * rng.next_f64() as f32
                        } else {
                            (rng.next_f64() * 8.0 - 4.0) as f32
                        }
                    })
                    .collect();
                Payload::from_f32(&vals)
            }
            Dtype::F64 => {
                let vals: Vec<f64> = (0..n)
                    .map(|_| {
                        if cfg.op == Op::Prod {
                            0.9 + 0.2 * rng.next_f64()
                        } else {
                            rng.next_f64() * 8.0 - 4.0
                        }
                    })
                    .collect();
                Payload::from_f64(&vals)
            }
        }
    }

    /// Run to completion.  Errors if the system deadlocks (events drained
    /// but some rank never finished) — the failure-injection tests rely
    /// on this surfacing instead of hanging.
    pub fn run(&mut self) -> Result<RunMetrics> {
        // first calls: random skew per rank + optional forced late rank
        for rank in 0..self.cfg.p {
            let mut jitter = if self.cfg.cost.start_jitter_ns > 0 {
                self.master_rng.next_below(self.cfg.cost.start_jitter_ns)
            } else {
                0
            };
            if self.cfg.late_rank == Some(rank) {
                jitter += self.cfg.late_delay_ns;
            }
            self.q.push(SimTime::ns(jitter), EventKind::HostStart { rank });
        }
        // background flows draw AFTER the rank-order jitter loop, so a
        // bg-off run consumes exactly the same rng stream as before
        for flow in 0..self.cfg.bg_flows {
            let src = self.master_rng.next_below(self.cfg.p as u64) as usize;
            let mut dst = self.master_rng.next_below(self.cfg.p as u64) as usize;
            if dst == src {
                dst = (dst + 1) % self.cfg.p;
            }
            let start = self.master_rng.next_below(self.cfg.bg_gap_ns);
            self.bg.push(BgFlow { src, dst, remaining: self.cfg.bg_msgs, seq: 0 });
            if self.cfg.bg_msgs > 0 {
                self.q.push(SimTime::ns(start), EventKind::BgTick { flow: flow as u16 });
            }
        }
        // crash-scheduled runs arm the liveness protocol: one low-rate
        // probe timer per rank (deterministically staggered — no RNG
        // draw, so the seed streams above are untouched) plus the
        // scheduled switch deaths.  Quiet and loss-only plans schedule
        // nothing here, keeping their event streams byte-identical.
        if self.fault.has_crashes() {
            for rank in 0..self.cfg.p {
                let at = SimTime::ns(self.cfg.cost.probe_interval_ns + rank as u64 * 64);
                self.q.push(at, EventKind::ProbeTimer { rank });
            }
            for (s, at_ns) in self.fault.switch_crashes() {
                self.q.push(SimTime::ns(at_ns), EventKind::CrashSwitch { node: self.cfg.p + s });
            }
        }
        // the watchdog turns any would-be hang into a named error; it
        // only arms alongside the failure machinery (one comparison per
        // pop otherwise changes nothing)
        let watchdog_armed = self.fault.lossy() && self.cfg.cost.watchdog_ns > 0;
        while let Some((now, ev)) = self.q.pop() {
            // self-profile bookkeeping costs two reads per pop and only
            // when enabled; wall-clock never feeds back into sim time
            let prof_start = self.profile.as_ref().map(|_| {
                (ev.index(), std::time::Instant::now(), crate::util::alloc::allocation_count())
            });
            match ev {
                EventKind::HostStart { rank } => self.on_host_start(now, rank),
                EventKind::HostRecv { rank, msg } => self.on_host_recv(now, rank, msg),
                EventKind::NicRecv { rank, port, frame } => {
                    self.on_nic_recv(now, rank, port, frame)
                }
                EventKind::NicHostReq { rank, req } => self.on_nic_host_req(now, rank, req),
                EventKind::HpuDone { rank } => self.on_hpu_done(now, rank),
                EventKind::BgTick { flow } => self.on_bg_tick(now, flow),
                EventKind::RetxTimer { rank, txn } => self.on_retx_timer(now, rank, txn),
                EventKind::ProbeTimer { rank } => self.on_probe_timer(now, rank),
                EventKind::CrashSwitch { node } => self.on_crash_switch(now, node),
            }
            if watchdog_armed
                && self.fatal.is_none()
                && now - self.last_progress > self.cfg.cost.watchdog_ns
            {
                self.fatal = Some(format!(
                    "watchdog: no collective progress for {} ns (last completion at {} ns) — \
                     aborting instead of hanging",
                    self.cfg.cost.watchdog_ns,
                    self.last_progress.as_ns()
                ));
            }
            if let (Some((idx, t0, a0)), Some(prof)) = (prof_start, self.profile.as_deref_mut()) {
                prof.pops += 1;
                prof.counts[idx] += 1;
                prof.wall_ns[idx] += t0.elapsed().as_nanos() as u64;
                prof.allocs[idx] +=
                    crate::util::alloc::allocation_count().saturating_sub(a0);
            }
            if self.fatal.is_some() {
                break;
            }
        }
        if let Some(f) = self.fatal.take() {
            bail!("{f}");
        }
        for (rank, h) in self.hosts.iter().enumerate() {
            if !h.done {
                let tcfg = &self.tenants[self.rank_tenant[rank]].cfg;
                bail!(
                    "deadlock: rank {rank} finished {}/{} iterations (algo {}, {})",
                    h.iter,
                    h.total_iters,
                    tcfg.algo.name(),
                    tcfg.series_name()
                );
            }
        }
        self.metrics.sim_ns = self.q.now().as_ns();
        for nic in &self.nics {
            let r = nic.rank;
            if r < self.cfg.p {
                self.metrics.frames_tx[r] = nic.frames_tx;
                self.metrics.bytes_tx[r] = nic.bytes_tx;
                self.metrics.frames_forwarded[r] = nic.frames_forwarded;
                self.metrics.hpu_queued += nic.hpu.queued_total;
            } else {
                // switch nodes pool into the trunk counters
                self.metrics.switch_frames_tx += nic.frames_tx;
                self.metrics.switch_bytes_tx += nic.bytes_tx;
                self.metrics.switch_frames_forwarded += nic.frames_forwarded;
            }
        }
        if let Some(a) = self.attr.take() {
            self.metrics.attribution = Some(Attribution::finalize(
                a.wire,
                a.switch_queue,
                a.hpu_queue,
                a.handler_exec,
                a.compute,
                a.recovery,
                a.total,
            ));
        }
        Ok(self.metrics.clone())
    }

    // ------------------------------------------------------------ hosts

    fn on_host_start(&mut self, now: SimTime, rank: Rank) {
        if self.dead[rank] {
            return; // fail-stopped: the host takes no further actions
        }
        if self.fault.rank_crash_epoch(rank) == Some(self.hosts[rank].iter)
            && self.hosts[rank].iter < self.hosts[rank].total_iters
        {
            // fail-stop at the top of the scheduled epoch: the rank dies
            // before contributing anything to it
            self.crash_rank(now, rank);
            return;
        }
        if self.degraded_tenants[self.rank_tenant[rank]] {
            // the shrunk group already completed its final epoch; the
            // survivor stream stops here
            self.hosts[rank].done = true;
            return;
        }
        let host = &mut self.hosts[rank];
        if host.iter >= host.total_iters {
            host.done = true;
            return;
        }
        assert!(!host.in_flight, "rank {rank} started a call while one is in flight");
        host.in_flight = true;
        host.call_time = now;
        let epoch = host.iter;
        self.trace
            .record(now, rank, TraceKind::HostCall, SpanData::instant((epoch & 0xFFFF) as u16));
        let ti = self.rank_tenant[rank];
        if self.attr.is_some() {
            let measured = epoch >= self.tenants[ti].cfg.warmup as u32;
            self.attr.as_deref_mut().expect("checked").measuring[rank] = measured;
        }
        let (comm, base, gsize) = {
            let t = &self.tenants[ti];
            (t.comm, t.base, t.size)
        };
        let payload = match &self.injected {
            Some(contribs) if epoch == 0 => contribs[rank].clone(),
            _ => Self::gen_payload(&self.tenants[ti].cfg, rank, epoch),
        };
        if self.cfg.verify {
            self.contributions
                .entry((comm, epoch))
                .or_insert_with(|| vec![None; gsize])[rank - base] = Some(payload.clone());
        }
        if self.tenants[ti].cfg.offloaded() {
            // craft the HostRequest packet and push it down the
            // (unoptimized) driver — the first of the two crossings the
            // paper identifies as the offload overhead.
            let mut req =
                build_request(&self.tenants[ti].cfg, rank, (epoch & 0xFFFF) as u16, payload);
            req.comm = comm;
            req.comm_size = gsize as u16;
            let at = now + self.cfg.cost.offload_ns(req.payload.byte_len());
            self.q.push(at, EventKind::NicHostReq { rank, req });
        } else {
            // software machines run in communicator-local rank space
            let (algo, coll, op) = {
                let c = &self.tenants[ti].cfg;
                (c.algo, c.coll, c.op)
            };
            let machine = self.hosts[rank]
                .sw
                .entry(epoch)
                .or_insert_with(|| make_sw(algo, rank - base, gsize, coll));
            let mut ctx = SwCtx {
                rank: rank - base,
                p: gsize,
                inclusive: coll.inclusive(),
                op,
                compute: &*self.compute,
                cost: &self.cfg.cost,
                elapsed_ns: 0,
            };
            let actions = machine.on_call(&mut ctx, &payload);
            let elapsed = ctx.elapsed_ns;
            self.process_sw_actions(now, rank, epoch, elapsed, actions);
        }
    }

    fn on_host_recv(&mut self, now: SimTime, rank: Rank, msg: HostMsg) {
        if self.dead[rank] {
            return; // messages to a fail-stopped host die at the edge
        }
        if self.degraded_tenants[self.rank_tenant[rank]] {
            // straggler deliveries from the aborted epoch — the shrunk
            // group already completed, nothing left to advance
            return;
        }
        match msg {
            HostMsg::Sw(m) => {
                let epoch = m.epoch;
                let ti = self.rank_tenant[rank];
                let (base, gsize) = {
                    let t = &self.tenants[ti];
                    (t.base, t.size)
                };
                let (algo, coll, op) = {
                    let c = &self.tenants[ti].cfg;
                    (c.algo, c.coll, c.op)
                };
                let machine = self.hosts[rank]
                    .sw
                    .entry(epoch)
                    .or_insert_with(|| make_sw(algo, rank - base, gsize, coll));
                let mut ctx = SwCtx {
                    rank: rank - base,
                    p: gsize,
                    inclusive: coll.inclusive(),
                    op,
                    compute: &*self.compute,
                    cost: &self.cfg.cost,
                    elapsed_ns: 0,
                };
                let actions = machine.on_msg(&mut ctx, &m);
                let elapsed = ctx.elapsed_ns;
                self.process_sw_actions(now, rank, epoch, elapsed, actions);
            }
            HostMsg::NfResult { epoch, payload, nic_elapsed_ns } => {
                let iter = self.hosts[rank].iter;
                debug_assert_eq!(epoch, (iter & 0xFFFF) as u16, "result for wrong epoch");
                let warmup = self.tenants[self.rank_tenant[rank]].cfg.warmup as u32;
                if iter >= warmup {
                    self.metrics.nic_elapsed[rank].record(nic_elapsed_ns);
                }
                self.complete_iteration(now, rank, iter, payload);
            }
        }
    }

    /// Walk a software activation's actions, charging host costs in
    /// program order: reduction time first, then one stack hand-off per
    /// send; completion timestamps where it falls in that order.
    fn process_sw_actions(
        &mut self,
        now: SimTime,
        rank: Rank,
        epoch: u32,
        compute_ns: u64,
        actions: Vec<SwAction>,
    ) {
        // software machines emit communicator-local destinations
        let base = self.tenants[self.rank_tenant[rank]].base;
        if compute_ns > 0 {
            self.attr_charge(rank, |a| a.compute += compute_ns);
        }
        let mut t = now + compute_ns;
        for action in actions {
            match action {
                SwAction::Send { dst, kind, step, payload } => {
                    t = t + self.cfg.cost.sw_send_ns(payload.byte_len());
                    self.send_sw_message(t, rank, base + dst, kind, step, epoch, payload);
                }
                SwAction::Complete { result } => {
                    self.complete_iteration(t, rank, epoch, result);
                }
            }
        }
        // retire the machine if it finished all its obligations
        if self.hosts[rank].sw.get(&epoch).is_some_and(|m| m.done()) {
            self.hosts[rank].sw.remove(&epoch);
        }
    }

    fn complete_iteration(&mut self, at: SimTime, rank: Rank, epoch: u32, result: Payload) {
        self.trace.record(
            at,
            rank,
            TraceKind::HostComplete,
            SpanData::instant((epoch & 0xFFFF) as u16),
        );
        let ti = self.rank_tenant[rank];
        let warmup = self.tenants[ti].cfg.warmup as u32;
        let host = &mut self.hosts[rank];
        assert!(host.in_flight, "completion without a call at rank {rank}");
        host.in_flight = false;
        let latency = at - host.call_time;
        if epoch >= warmup {
            self.metrics.host_latency[rank].record(latency);
            self.metrics.tenant_host[ti].record(latency);
            if let Some(a) = self.attr.as_deref_mut() {
                a.total += latency;
                self.metrics.host_hist.record(latency);
            }
        }
        host.iter += 1;
        self.last_progress = self.last_progress.max(at);
        let gap = self.cfg.cost.host_call_gap_ns;
        self.q.push(at + gap, EventKind::HostStart { rank });

        if self.injected.is_some() && epoch == 0 {
            self.results[rank] = Some(result.clone());
        }
        if self.cfg.verify {
            self.verify_result(rank, epoch, &result);
        }
    }

    fn verify_result(&mut self, rank: Rank, epoch: u32, result: &Payload) {
        let ti = self.rank_tenant[rank];
        let (comm, base, gsize) = {
            let t = &self.tenants[ti];
            (t.comm, t.base, t.size)
        };
        let (coll, op, dtype, elems) = {
            let c = &self.tenants[ti].cfg;
            (c.coll, c.op, c.dtype, c.msg_elems())
        };
        let series = self.tenants[ti].cfg.series_name();
        if self.degraded.contains(&(comm, epoch)) {
            // shrunk-group completion: the value came from the survivor
            // oracle itself (abort-and-shrink is modeled analytically),
            // so there is nothing independent to compare in-run — the
            // crash corpus and property tests cross-check these values
            // against externally computed survivor oracles instead
            self.retire_verified(comm, epoch, gsize);
            return;
        }
        // contributions are communicator-locally indexed, one table per
        // (tenant, epoch): tenants verify fully independently
        let contribs = self
            .contributions
            .get(&(comm, epoch))
            .unwrap_or_else(|| panic!("no contributions for tenant {comm} epoch {epoch}"));
        use crate::packet::CollType as Ct;
        if coll == Ct::Bcast {
            // every rank must receive the communicator root's contribution
            let want =
                contribs[0].clone().expect("bcast completion implies the root contributed");
            assert_payload_matches(result, &want, rank, epoch, &series);
            self.retire_verified(comm, epoch, gsize);
            return;
        }
        if matches!(coll, Ct::Allreduce | Ct::Barrier) {
            // every rank of the communicator receives the full reduction;
            // completion implies all its ranks contributed
            let present: Vec<Payload> = contribs
                .iter()
                .map(|c| c.clone().expect("allreduce completion implies all contributions"))
                .collect();
            let want =
                oracle_prefix(&*self.compute, &present, op, true, gsize - 1).expect("oracle");
            assert_payload_matches(result, &want, rank, epoch, &series);
            self.retire_verified(comm, epoch, gsize);
            return;
        }
        let inclusive = coll.inclusive();
        // the scan runs within the rank's communicator: its result
        // depends only on contributions base..=rank (exclusive: ..rank);
        // later ranks may not even have called yet.
        let local = rank - base;
        let needed = if inclusive { local + 1 } else { local };
        let present: Vec<Payload> = contribs
            .iter()
            .take(needed.max(1))
            .map(|c| c.clone().unwrap_or_else(|| panic!("missing contribution below {rank}")))
            .collect();
        let want = if inclusive {
            oracle_prefix(&*self.compute, &present, op, true, local).expect("oracle")
        } else if local == 0 {
            Payload::identity(dtype, op, elems)
        } else {
            // exclusive prefix of rank j == inclusive prefix of rank j-1
            oracle_prefix(&*self.compute, &present, op, true, local - 1).expect("oracle")
        };
        assert_payload_matches(result, &want, rank, epoch, &series);
        self.retire_verified(comm, epoch, gsize);
    }

    /// Count one verified rank for `(comm, epoch)`; drop the bookkeeping
    /// once the whole communicator checked out.
    fn retire_verified(&mut self, comm: u16, epoch: u32, gsize: usize) {
        let count = self.verified_counts.entry((comm, epoch)).or_insert(0);
        *count += 1;
        if *count == gsize {
            self.contributions.remove(&(comm, epoch));
            self.verified_counts.remove(&(comm, epoch));
        }
    }

    // ------------------------------------------------------------- wire

    /// Fragment + frame + route one software message into the sender's
    /// NIC, ready at `ready` (stack hand-off complete).
    fn send_sw_message(
        &mut self,
        ready: SimTime,
        src: Rank,
        dst: Rank,
        kind: crate::net::SwMsgKind,
        step: u16,
        epoch: u32,
        payload: Payload,
    ) {
        let count = payload.len() as u32;
        let ti = self.rank_tenant[src];
        let algo = self.tenants[ti].cfg.algo.wire_code();
        // SwMsg.src is communicator-local (the algorithms reason in local
        // rank space); the frame addresses stay global.
        let base = self.tenants[ti].base;
        for (frag_idx, frag_total, _off, chunk) in fragment(&payload) {
            let msg = SwMsg {
                src: src - base,
                algo,
                kind,
                epoch,
                step,
                count,
                frag_idx,
                frag_total,
                payload: chunk,
            };
            let frame = Frame::new(src, dst, FrameBody::Sw(msg));
            self.transmit(src, dst, frame, ready);
        }
    }

    /// Transmit one frame from `src`'s NIC towards `dst` (first hop).
    /// Under a lossy fault plan, data frames leaving their origin are
    /// tagged with a transaction id and registered for timeout/
    /// retransmit recovery; acks and background noise stay unreliable.
    fn transmit(&mut self, src: Rank, dst: Rank, mut frame: Frame, ready: SimTime) {
        if self.fault.lossy()
            && frame.txn == 0
            && frame.src == src
            && matches!(frame.body, FrameBody::Coll(_) | FrameBody::Sw(_) | FrameBody::Probe(_))
        {
            let txn = self.next_txn;
            self.next_txn += 1;
            frame.txn = txn;
            self.nics[src]
                .pending
                .insert(txn, PendingTx { frame: frame.clone(), retries: 0, first_send: ready });
            let at = ready + self.cfg.cost.retx_timeout_ns(0);
            self.q.push(at, EventKind::RetxTimer { rank: src, txn });
        }
        let Some(port) = self.routes.next_hop(src, dst) else {
            if self.fault.lossy() {
                // the destination became unreachable (dead node or
                // post-reroute hole): the frame dies here and the
                // retransmit/suspicion machinery owns what happens next
                return;
            }
            panic!("no route {src} -> {dst} on {}", self.topo.name());
        };
        self.transmit_on_port(src, port, frame, ready);
    }

    fn transmit_on_port(&mut self, src: Rank, port: PortNo, mut frame: Frame, ready: SimTime) {
        let wire = frame.wire_bytes();
        let mut tx_ns = self.cfg.cost.tx_ns(wire);
        if self.fault.degrades() && src >= self.cfg.p {
            // degraded trunk: switch uplinks serialize slower
            tx_ns = self.fault.scaled_tx_ns(tx_ns);
        }
        let nic = &mut self.nics[src];
        let (start, end) = nic.tx_reserve(port, ready, tx_ns);
        nic.note_bytes(wire);
        // attribution: wire time goes to the frame's origin rank (the
        // only rank whose latency it can be part of); the port-FIFO
        // wait is switch/trunk queueing.  Background noise is
        // interference, never collective work, and is never charged.
        let origin = if src < self.cfg.p { src } else { frame.src };
        let is_bg = matches!(frame.body, FrameBody::Bg(_));
        if !is_bg {
            let queued = start - ready;
            self.attr_charge(origin, |a| {
                a.switch_queue += queued;
                a.wire += tx_ns;
            });
        }
        if self.trace.enabled() {
            let epoch = frame_epoch(&frame);
            if start > ready {
                self.trace
                    .record(ready, src, TraceKind::SwitchQueue, SpanData::span(start, epoch));
            }
            self.trace.record(
                start,
                src,
                TraceKind::NicSend,
                SpanData::span(end, epoch).txn(frame.txn).arg(frame.dst as u64),
            );
        }
        let (neighbor, nport) = self
            .topo
            .neighbor(src, port)
            .unwrap_or_else(|| panic!("dangling port {port} on rank {src}"));
        let mut hold = 0;
        if self.fault.lossy() {
            match self.fault.link_fault(src, neighbor) {
                Some(LinkFault::Drop) => {
                    // the frame left the card (serialization was charged)
                    // but dies on the wire: no arrival event
                    if self.trace.enabled() {
                        self.trace.record(
                            end,
                            src,
                            TraceKind::Dropped,
                            SpanData::instant(frame_epoch(&frame)).txn(frame.txn),
                        );
                    }
                    return;
                }
                Some(LinkFault::Corrupt) => {
                    // bits flip in flight: the frame still arrives and
                    // costs its wire time, but the receiver's CRC check
                    // will discard it (recovery-wise a drop)
                    frame.corrupt = true;
                }
                Some(LinkFault::Reorder) => {
                    // park the frame past its normal arrival so a
                    // back-to-back successor overtakes it
                    hold = REORDER_HOLD_NS;
                }
                None => {}
            }
        }
        if !is_bg {
            let prop = self.cfg.cost.link_prop_ns;
            self.attr_charge(origin, |a| a.wire += prop);
        }
        let arrival = end + self.cfg.cost.link_prop_ns + hold;
        self.q.push(arrival, EventKind::NicRecv { rank: neighbor, port: nport, frame });
    }

    // -------------------------------------------------------------- nics

    fn on_nic_recv(&mut self, now: SimTime, rank: Rank, _port: PortNo, frame: Frame) {
        if self.dead[rank] {
            // a fail-stopped card neither forwards nor terminates
            // anything: the frame dies in flight, and if it was reliable
            // its sender's retransmit timer owns recovery
            return;
        }
        if frame.dst != rank {
            // store-and-forward towards the destination: either the
            // reference-router path of an intermediate NetFPGA (topology/
            // algorithm mismatch penalty) or a switch of the hierarchical
            // presets.  Each hop charges its forwarding latency here and
            // wire serialization + propagation in `transmit` — shared
            // trunks serialize through the output-port FIFO.
            self.nics[rank].frames_forwarded += 1;
            let fwd_ns = if rank >= self.cfg.p {
                self.cfg.cost.switch_fwd_ns
            } else {
                self.cfg.cost.nic_fwd_cycles * 8
            };
            let ready = now + fwd_ns;
            let dst = frame.dst;
            self.transmit(rank, dst, frame, ready);
            return;
        }
        if self.trace.enabled() {
            self.trace.record(
                now,
                rank,
                TraceKind::NicRecvd,
                SpanData::instant(frame_epoch(&frame)).txn(frame.txn),
            );
        }
        if frame.corrupt {
            // the wire CRC fails at ingress: the frame is discarded
            // before any protocol processing — no ack, no liveness
            // update (a mangled source field cannot be trusted), so the
            // sender's retransmit timer recovers it exactly like a drop
            if self.trace.enabled() {
                self.trace.record(
                    now,
                    rank,
                    TraceKind::Dropped,
                    SpanData::instant(frame_epoch(&frame)).txn(frame.txn),
                );
            }
            return;
        }
        if self.fault.has_crashes() {
            // liveness piggybacks on every clean arrival from the
            // origin: data, acks and probes all refresh the peer
            self.nics[rank].last_heard.insert(frame.src, now);
        }
        if frame.txn != 0 {
            // reliability layer: ack every reliable frame end-to-end
            // (the ack itself is unreliable — a lost ack just means one
            // spurious retransmit, which the dedup below absorbs)
            let ack = Frame::new(rank, frame.src, FrameBody::RelAck(RelAck { txn: frame.txn }));
            let ready = now + self.cfg.cost.nic_fwd_cycles * 8;
            self.transmit(rank, frame.src, ack, ready);
            if !self.nics[rank].seen_txns.insert(frame.txn) {
                // duplicate delivery (retransmit raced the ack): re-acked
                // above, suppressed here
                return;
            }
        }
        match frame.body {
            FrameBody::Sw(msg) => {
                // plain NIC behaviour: climb the host stack; reassemble at
                // the socket layer, charge the receive cost once per
                // message.
                let key = (msg.src, msg.kind as u16, msg.step, msg.epoch);
                let total_bytes = msg.count as usize * msg.payload.dtype().size();
                let reasm = &mut self.hosts[rank].sw_reasm;
                let whole =
                    reasm.add(key, msg.frag_idx, msg.frag_total, msg.count, msg.payload.clone());
                if let Some(whole) = whole {
                    let full = SwMsg { payload: whole, frag_idx: 0, frag_total: 1, ..msg };
                    let at = now + self.cfg.cost.sw_recv_ns(total_bytes);
                    self.q.push(at, EventKind::HostRecv { rank, msg: HostMsg::Sw(full) });
                }
            }
            FrameBody::Coll(pkt) => {
                let key = (pkt.rank as Rank, pkt.msg_type.wire_code(), pkt.step, pkt.epoch());
                let reasm = &mut self.nics[rank].reasm;
                let whole =
                    reasm.add(key, pkt.frag_idx, pkt.frag_total, pkt.count, pkt.payload.clone());
                if let Some(whole) = whole {
                    let full = CollPacket { payload: whole, frag_idx: 0, frag_total: 1, ..pkt };
                    self.activate_engine(now, rank, full.epoch(), None, Some(full));
                }
            }
            FrameBody::Bg(_) => {
                // background traffic terminates at the NIC: it exists to
                // contend for wire and port-FIFO time, not to reach hosts
                self.metrics.bg_frames_rx += 1;
            }
            FrameBody::Probe(_) => {
                // liveness probe: nothing to deliver — the reliable-layer
                // ack above is the whole reply, and the last_heard
                // refresh already happened
            }
            FrameBody::RelAck(ack) => {
                if let Some(p) = self.nics[rank].pending.remove(&ack.txn) {
                    self.trace.record(
                        now,
                        rank,
                        TraceKind::NicAck,
                        SpanData::instant(frame_epoch(&p.frame)).txn(ack.txn),
                    );
                    if p.retries > 0 {
                        // recovery latency: original send to eventual ack
                        let rec = now - p.first_send;
                        self.metrics.recovery_ns += rec;
                        self.attr_charge(rank, |a| a.recovery += rec);
                    }
                }
                // a duplicate ack (from a retransmit that raced the
                // first ack) finds no pending entry and is ignored
            }
        }
    }

    fn on_nic_host_req(&mut self, now: SimTime, rank: Rank, req: OffloadRequest) {
        if self.dead[rank] || self.degraded_tenants[self.rank_tenant[rank]] {
            return;
        }
        self.trace.record(now, rank, TraceKind::Offload, SpanData::instant(req.epoch));
        self.nics[rank].regs.stamp_offload(req.epoch, now);
        self.activate_engine(now, rank, req.epoch, Some(req), None);
    }

    /// Admit one engine activation to the NIC's handler pool.  The
    /// fixed-function path (and an unconstrained pool, `cost.hpus == 0`)
    /// runs inline exactly as before — no extra events, byte-identical
    /// schedule.  A constrained handler pool parks the activation when
    /// all units are busy; it runs later from [`Cluster::on_hpu_done`]
    /// with the wait charged as queueing delay.
    fn activate_engine(
        &mut self,
        now: SimTime,
        rank: Rank,
        epoch: u16,
        req: Option<OffloadRequest>,
        pkt: Option<CollPacket>,
    ) {
        let ti = self.rank_tenant[rank];
        let constrained = self.tenants[ti].cfg.handler() && self.cfg.cost.hpus > 0;
        if constrained {
            if self.nics[rank].hpu.saturated() {
                let comm = self.tenants[ti].comm;
                let flow = CollPacket::make_comm_id(comm, epoch);
                self.nics[rank].hpu.enqueue(flow, HpuJob { epoch, req, pkt, arrival: now });
                return;
            }
            self.nics[rank].hpu.busy += 1;
        }
        self.run_activation(now, rank, epoch, req, pkt, constrained);
    }

    /// A handler unit retired its activation: run the next parked job
    /// (round-robin across flows), or free the unit.
    fn on_hpu_done(&mut self, now: SimTime, rank: Rank) {
        if self.dead[rank] {
            return;
        }
        if let Some(job) = self.nics[rank].hpu.next() {
            let waited = now - job.arrival;
            self.metrics.hpu_queue_ns += waited;
            self.attr_charge(rank, |a| a.hpu_queue += waited);
            if self.trace.enabled() && waited > 0 {
                self.trace.record(
                    job.arrival,
                    rank,
                    TraceKind::HpuQueue,
                    SpanData::span(now, job.epoch),
                );
            }
            self.run_activation(now, rank, job.epoch, job.req, job.pkt, true);
        } else {
            self.nics[rank].hpu.busy -= 1;
        }
    }

    /// Inject one background frame and reschedule the flow's next tick.
    fn on_bg_tick(&mut self, now: SimTime, flow: u16) {
        if self.dead[self.bg[flow as usize].src] {
            return; // the injecting card died; the flow dies with it
        }
        let (src, dst, seq, remaining) = {
            let f = &mut self.bg[flow as usize];
            f.remaining -= 1;
            f.seq += 1;
            (f.src, f.dst, f.seq, f.remaining)
        };
        let msg = BgMsg { flow, seq, len: self.cfg.bg_bytes as u32 };
        let frame = Frame::new(src, dst, FrameBody::Bg(msg));
        self.transmit(src, dst, frame, now);
        if remaining > 0 {
            self.q.push(now + self.cfg.bg_gap_ns, EventKind::BgTick { flow });
        }
    }

    /// A reliable frame's retransmit timer expired.  A no-op if the ack
    /// already landed; otherwise the datapath decides whether to replay
    /// the frame — the handler path runs the program's `on_timer` entry
    /// on the VM, the fixed-function and software paths hard-wire the
    /// same policy — or gives up with a named, non-hanging failure.
    fn on_retx_timer(&mut self, now: SimTime, rank: Rank, txn: u64) {
        if self.dead[rank] {
            return; // a dead card retransmits nothing
        }
        let Some(p) = self.nics[rank].pending.get(&txn) else {
            return; // acked in time
        };
        let retries = p.retries;
        let dst = p.frame.dst;
        let runs_vm = matches!(p.frame.body, FrameBody::Coll(_) | FrameBody::Probe(_));
        let epoch = match &p.frame.body {
            FrameBody::Coll(pkt) => pkt.epoch() as u32,
            FrameBody::Sw(m) => m.epoch,
            _ => 0,
        };
        self.metrics.timeouts_fired += 1;
        self.trace.record(
            now,
            rank,
            TraceKind::Timeout,
            SpanData::instant((epoch & 0xFFFF) as u16).txn(txn),
        );
        let max_retries = self.cfg.cost.max_retries;
        let ti = self.rank_tenant[rank];
        let (retransmit, cycles) = if self.tenants[ti].cfg.handler() && runs_vm {
            self.run_timer_program(rank, (epoch & 0xFFFF) as u16, retries, max_retries)
        } else {
            (retries < max_retries, self.cfg.cost.nic_pipeline_cycles)
        };
        if !retransmit {
            self.nics[rank].pending.remove(&txn);
            if self.fault.has_crashes() {
                // under the fail-stop model a give-up is not fatal: it is
                // the suspicion signal.  Declare the silent peer dead and
                // let the survivors shrink or surface a partition.
                self.declare_dead(now, dst);
                return;
            }
            let tcfg = &self.tenants[ti].cfg;
            self.fatal = Some(format!(
                "recovery failed: ({}, rank {rank}, epoch {epoch}) gave up on txn {txn} \
                 after {retries} retransmits ({})",
                tcfg.coll.name(),
                tcfg.series_name()
            ));
            return;
        }
        let p = self.nics[rank].pending.get_mut(&txn).expect("still pending");
        p.retries += 1;
        let retries = p.retries;
        let frame = p.frame.clone();
        self.metrics.retransmits += 1;
        let dst = frame.dst;
        let ready = now + cycles * 8;
        self.trace.record(
            ready,
            rank,
            TraceKind::Retransmit,
            SpanData::instant((epoch & 0xFFFF) as u16).txn(txn).arg(retries as u64),
        );
        self.transmit(rank, dst, frame, ready);
        let at = ready + self.cfg.cost.retx_timeout_ns(retries);
        self.q.push(at, EventKind::RetxTimer { rank, txn });
    }

    // -------------------------------------------------- fail-stop faults

    /// A scheduled rank crash fires: the host and its card fail-stop
    /// together, silently.  Survivors find out through the liveness
    /// protocol (ack silence / probe give-up), never through this call.
    fn crash_rank(&mut self, now: SimTime, rank: Rank) {
        self.dead[rank] = true;
        self.crash_times.insert(rank, now);
        self.metrics.crashes += 1;
        let host = &mut self.hosts[rank];
        host.in_flight = false;
        host.done = true; // a dead rank owes the driver nothing further
        // the card dies with the host: nothing pending will ever resend
        self.nics[rank].pending.clear();
        if let Some(a) = self.attr.as_deref_mut() {
            a.measuring[rank] = false;
        }
    }

    /// A scheduled switch death fires: mark the node dead, reroute the
    /// fabric around it, and fail loudly if that partitions survivors.
    fn on_crash_switch(&mut self, now: SimTime, node: usize) {
        if self.dead[node] {
            return;
        }
        self.dead[node] = true;
        self.crash_times.insert(node, now);
        self.metrics.crashes += 1;
        self.nics[node].pending.clear();
        self.rebuild_routes_and_check("switch death");
    }

    /// The survivors' verdict on a silent peer: exclude it, reroute, and
    /// shrink its communicator.  Deduplicated — later give-ups against
    /// the same peer are no-ops.  Only rank peers are declared here;
    /// switch deaths arrive via their own scheduled event.
    fn declare_dead(&mut self, now: SimTime, suspect: Rank) {
        if suspect >= self.cfg.p || self.dead_declared[suspect] {
            return;
        }
        self.dead_declared[suspect] = true;
        match self.crash_times.get(&suspect) {
            Some(&died) => self.metrics.detection_ns += now - died,
            None => {
                // the peer was alive: an over-aggressive timeout evicted
                // it anyway (the fail-stop detector's inherent risk)
                self.metrics.false_suspicions += 1;
            }
        }
        if !self.dead[suspect] {
            // exclusion is fail-stop from the group's point of view even
            // when the suspicion was false: the evicted rank stops
            self.dead[suspect] = true;
            self.hosts[suspect].in_flight = false;
            self.hosts[suspect].done = true;
            self.nics[suspect].pending.clear();
            if let Some(a) = self.attr.as_deref_mut() {
                a.measuring[suspect] = false;
            }
        }
        self.rebuild_routes_and_check("rank exclusion");
        if self.fatal.is_some() {
            return;
        }
        self.degrade_tenant(now, self.rank_tenant[suspect]);
    }

    /// Recompute BFS routes around every dead node and check that all
    /// live rank pairs of non-degraded tenants can still reach each
    /// other; an unreachable pair is a named partition error (no
    /// protocol can terminate across it, so continuing would hang).
    fn rebuild_routes_and_check(&mut self, cause: &str) {
        self.routes = RouteTable::build_avoiding(&self.topo, &self.dead);
        for (ti, t) in self.tenants.iter().enumerate() {
            if self.degraded_tenants[ti] {
                continue;
            }
            let live: Vec<Rank> =
                (t.base..t.base + t.size).filter(|&r| !self.dead[r]).collect();
            for &a in &live {
                for &b in &live {
                    if a != b && !self.routes.reaches(a, b) {
                        self.fatal = Some(format!(
                            "partition: ranks {a} and {b} (tenant {}) cannot reach each other \
                             after {cause} on {}",
                            t.comm,
                            self.topo.name()
                        ));
                        return;
                    }
                }
            }
        }
        self.metrics.reroutes += 1;
    }

    /// Graceful degradation: the shrunk survivor group of tenant `ti`
    /// aborts its in-flight epoch and completes it over the survivor
    /// communicator — each live caller gets the survivor-oracle value
    /// for ITS in-flight epoch (pipelined ranks may be on different
    /// epochs), then the stream stops.  A Bcast whose root died has no
    /// survivor holding the data: that is a structured named failure.
    fn degrade_tenant(&mut self, now: SimTime, ti: usize) {
        if self.degraded_tenants[ti] {
            return;
        }
        self.degraded_tenants[ti] = true;
        let (comm, base, gsize) = {
            let t = &self.tenants[ti];
            (t.comm, t.base, t.size)
        };
        let tcfg = self.tenants[ti].cfg.clone();
        let dead_local: Vec<bool> = (0..gsize).map(|i| self.dead[base + i]).collect();
        let dead_ranks: Vec<Rank> =
            (0..gsize).filter(|&i| dead_local[i]).map(|i| base + i).collect();
        let stuck: Vec<(Rank, u32)> = (base..base + gsize)
            .filter(|&g| !self.dead[g] && self.hosts[g].in_flight)
            .map(|g| (g, self.hosts[g].iter))
            .collect();
        if tcfg.coll == crate::packet::CollType::Bcast && dead_local[0] {
            let epoch = stuck.first().map(|&(_, e)| e).unwrap_or(0);
            self.fatal = Some(format!(
                "degraded failure: (coll {}, epoch {epoch}, dead ranks {dead_ranks:?}) — \
                 the root died and no survivor holds its data",
                tcfg.coll.name()
            ));
            return;
        }
        for (g, epoch) in stuck {
            let result = self.survivor_result(&tcfg, comm, base, gsize, g, epoch, &dead_local);
            self.degraded.insert((comm, epoch));
            self.metrics.degraded_completions += 1;
            // abort + shrink is charged one host call gap: the survivors
            // already hold their partial state, the group agreement rides
            // the detection latency that elapsed before this call
            self.complete_iteration(now + self.cfg.cost.host_call_gap_ns, g, epoch, result);
        }
    }

    /// A rank's contribution to `(comm, epoch)` as the survivor oracle
    /// needs it: the recorded one if the rank got far enough to
    /// contribute, otherwise regenerated from the deterministic
    /// generator (or the injected application data for epoch 0).
    fn survivor_contribution(
        &self,
        tcfg: &ExpConfig,
        comm: u16,
        base: usize,
        epoch: u32,
        local: usize,
    ) -> Payload {
        if let Some(c) =
            self.contributions.get(&(comm, epoch)).and_then(|v| v[local].clone())
        {
            return c;
        }
        if epoch == 0 {
            if let Some(inj) = &self.injected {
                return inj[base + local].clone();
            }
        }
        Cluster::gen_payload(tcfg, base + local, epoch)
    }

    /// The shrunk-group result for global rank `g` at `epoch`: the
    /// collective recomputed over survivor contributions only, in
    /// original rank order (ULFM-shrink semantics — survivors keep their
    /// relative order, dead ranks simply vanish from the fold).
    fn survivor_result(
        &self,
        tcfg: &ExpConfig,
        comm: u16,
        base: usize,
        gsize: usize,
        g: Rank,
        epoch: u32,
        dead_local: &[bool],
    ) -> Payload {
        use crate::packet::CollType as Ct;
        if tcfg.coll == Ct::Bcast {
            // root survived (the dead-root case errored before this)
            return self.survivor_contribution(tcfg, comm, base, epoch, 0);
        }
        let live: Vec<usize> = (0..gsize).filter(|&i| !dead_local[i]).collect();
        let present: Vec<Payload> = live
            .iter()
            .map(|&i| self.survivor_contribution(tcfg, comm, base, epoch, i))
            .collect();
        let sidx = live
            .iter()
            .position(|&i| i == g - base)
            .expect("degraded completion only reaches live ranks");
        match tcfg.coll {
            Ct::Allreduce | Ct::Barrier => {
                oracle_prefix(&*self.compute, &present, tcfg.op, true, live.len() - 1)
                    .expect("survivor oracle")
            }
            _ if tcfg.coll.inclusive() => {
                oracle_prefix(&*self.compute, &present, tcfg.op, true, sidx)
                    .expect("survivor oracle")
            }
            _ if sidx == 0 => Payload::identity(tcfg.dtype, tcfg.op, tcfg.msg_elems()),
            _ => oracle_prefix(&*self.compute, &present, tcfg.op, true, sidx - 1)
                .expect("survivor oracle"),
        }
    }

    /// The low-rate liveness probe timer (crash-scheduled runs only).
    /// Each rank monitors its ring successor within its communicator;
    /// if the peer has been silent for a probe interval, a reliable
    /// Probe frame goes out — its ack refreshes liveness, and its
    /// retransmit give-up is the suspicion verdict.
    fn on_probe_timer(&mut self, now: SimTime, rank: Rank) {
        if self.dead[rank] || self.hosts[rank].done {
            return; // dead or retired cards stop probing (and re-arming)
        }
        let ti = self.rank_tenant[rank];
        if self.degraded_tenants[ti] {
            return;
        }
        let (base, gsize) = {
            let t = &self.tenants[ti];
            (t.base, t.size)
        };
        if gsize > 1 {
            let peer = base + ((rank - base + 1) % gsize);
            let interval = self.cfg.cost.probe_interval_ns;
            let fresh = self.nics[rank]
                .last_heard
                .get(&peer)
                .is_some_and(|&heard| now - heard < interval);
            if !fresh && !self.dead_declared[peer] {
                let nic = &mut self.nics[rank];
                nic.probe_seq += 1;
                nic.probes_tx += 1;
                let seq = nic.probe_seq;
                let frame = Frame::new(rank, peer, FrameBody::Probe(Probe { seq }));
                self.transmit(rank, peer, frame, now);
            }
        }
        self.q.push(now + self.cfg.cost.probe_interval_ns, EventKind::ProbeTimer { rank });
    }

    /// Run the handler program's `on_timer` entry for a timed-out frame
    /// on `rank`'s card: an ephemeral activation (timers carry no packet
    /// and touch no flow state).  Returns the program's verdict (true =
    /// retransmit) and the cycles to charge before the replay hits the
    /// wire.
    fn run_timer_program(
        &mut self,
        rank: Rank,
        epoch: u16,
        retries: u32,
        max_retries: u32,
    ) -> (bool, u64) {
        let ti = self.rank_tenant[rank];
        let (base, gsize) = {
            let t = &self.tenants[ti];
            (t.base, t.size)
        };
        let (coll, op) = {
            let c = &self.tenants[ti].cfg;
            (c.coll, c.op)
        };
        let prog = crate::nic::program_for(coll);
        let mut flow = crate::nic::Flow::new();
        let mut ctx = EngineCtx {
            rank: rank - base,
            p: gsize,
            inclusive: coll.inclusive(),
            op,
            coll,
            epoch,
            compute: &*self.compute,
            cost: &self.cfg.cost,
            cycles: 0,
            combine_cycles: 0,
            instrs: 0,
            stalls: 0,
        };
        let actions = crate::nic::vm::run(
            prog,
            &mut flow,
            &mut ctx,
            crate::nic::Activation::Timer { retries, max_retries },
        );
        self.metrics.handler_instrs += ctx.instrs;
        self.metrics.handler_stalls += ctx.stalls;
        let cycles = self.cfg.cost.nic_pipeline_cycles + ctx.cycles;
        (actions.iter().any(|a| matches!(a, NicAction::Retransmit)), cycles)
    }

    /// Run one engine activation and realize its actions on the wire /
    /// host boundary.  Engines run in communicator-local rank space; this
    /// is the (comm_id -> collective state) table of the paper's SSVI.
    /// `holds_unit` means the activation occupies a handler processing
    /// unit until it completes (`ready`), at which point `HpuDone` fires.
    fn run_activation(
        &mut self,
        now: SimTime,
        rank: Rank,
        epoch: u16,
        req: Option<OffloadRequest>,
        pkt: Option<CollPacket>,
        holds_unit: bool,
    ) {
        let ti = self.rank_tenant[rank];
        let (comm, base, gsize) = {
            let t = &self.tenants[ti];
            (t.comm, t.base, t.size)
        };
        let (algo, coll, op, handler, multicast_opt, ack_enabled) = {
            let c = &self.tenants[ti].cfg;
            (c.algo, c.coll, c.op, c.handler(), c.multicast_opt, c.ack_enabled)
        };
        let opts = EngineOpts { multicast_opt, ack_enabled };
        let comm_key = CollPacket::make_comm_id(comm, epoch);
        let local = rank - base;
        let nic = &mut self.nics[rank];
        let engine = nic.engines.entry(comm_key).or_insert_with(|| {
            if handler {
                // sPIN-style path: one handler-VM flow per invocation
                // instead of a fixed-function state machine
                crate::nic::handler_engine(coll)
            } else {
                make_engine(algo, local, gsize, coll, opts)
            }
        });
        let mut ctx = EngineCtx {
            rank: local,
            p: gsize,
            inclusive: coll.inclusive(),
            op,
            coll,
            epoch,
            compute: &*self.compute,
            cost: &self.cfg.cost,
            cycles: 0,
            combine_cycles: 0,
            instrs: 0,
            stalls: 0,
        };
        // the engine sees communicator-local requests
        let req = req.map(|mut r| {
            r.rank = local;
            r
        });
        let actions = match (&req, &pkt) {
            (Some(r), None) => engine.on_host_request(&mut ctx, r),
            (None, Some(k)) => engine.on_packet(&mut ctx, k),
            _ => unreachable!("exactly one of req/pkt"),
        };
        // packet-generation cost: one per unicast/deliver, ONE per
        // multicast regardless of fan-out (the SSIII-C saving).
        let generations = actions.len() as u64;
        self.metrics.multicasts +=
            actions.iter().filter(|a| matches!(a, NicAction::Multicast { .. })).count() as u64;
        let cycles = self.cfg.cost.nic_pipeline_cycles
            + ctx.cycles
            + generations * self.cfg.cost.nic_pkt_gen_cycles;
        self.metrics.handler_instrs += ctx.instrs;
        self.metrics.handler_stalls += ctx.stalls;
        let combine_cycles = ctx.combine_cycles;
        let ready = now + cycles * 8;
        // activation time splits into combine arithmetic (compute) and
        // everything else (pipeline, packet handling, VM retirement)
        let combine_ns = combine_cycles * 8;
        let exec_ns = cycles * 8 - combine_ns;
        self.attr_charge(rank, |a| {
            a.handler_exec += exec_ns;
            a.compute += combine_ns;
        });
        if self.trace.enabled() {
            self.trace.record(now, rank, TraceKind::HandlerExec, SpanData::span(ready, epoch));
            if combine_cycles > 0 {
                self.trace.record(
                    ready,
                    rank,
                    TraceKind::Combine,
                    SpanData::instant(epoch).arg(combine_cycles),
                );
            }
        }
        self.nics[rank].check_engine_pressure();
        self.process_nic_actions(ready, rank, epoch, actions);
        self.nics[rank].gc_engines();
        if holds_unit {
            // the unit is occupied for the activation's full runtime
            self.q.push(ready, EventKind::HpuDone { rank });
        }
    }

    fn process_nic_actions(
        &mut self,
        ready: SimTime,
        rank: Rank,
        epoch: u16,
        actions: Vec<NicAction>,
    ) {
        // engines emit communicator-local destinations
        let base = self.tenants[self.rank_tenant[rank]].base;
        for action in actions {
            match action {
                NicAction::Send { dst, mt, step, tag, payload } => {
                    self.send_coll(ready, rank, base + dst, epoch, mt, step, tag, payload);
                }
                NicAction::Multicast { dsts, mt, step, tag, payload } => {
                    // the multicast engine drives all target ports from one
                    // buffer: every copy becomes ready at the same instant,
                    // shared ports serialize via the port FIFO.
                    for dst in dsts {
                        self.send_coll(
                            ready,
                            rank,
                            base + dst,
                            epoch,
                            mt,
                            step,
                            tag,
                            payload.clone(),
                        );
                    }
                }
                NicAction::Retransmit => {
                    unreachable!("engine emitted Retransmit outside a timer activation")
                }
                NicAction::Deliver { payload } => {
                    // release timestamp + the second host crossing
                    self.trace.record(ready, rank, TraceKind::NicResult, SpanData::instant(epoch));
                    let elapsed = self.nics[rank].regs.stamp_release(epoch, ready);
                    let at = ready + self.cfg.cost.result_ns(payload.byte_len());
                    self.q.push(
                        at,
                        EventKind::HostRecv {
                            rank,
                            msg: HostMsg::NfResult {
                                epoch,
                                payload,
                                nic_elapsed_ns: elapsed,
                            },
                        },
                    );
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn send_coll(
        &mut self,
        ready: SimTime,
        src: Rank,
        dst: Rank,
        epoch: u16,
        mt: MsgType,
        step: u16,
        tag: u32,
        payload: Payload,
    ) {
        let ti = self.rank_tenant[src];
        let (comm, base, gsize) = {
            let t = &self.tenants[ti];
            (t.comm, t.base, t.size)
        };
        let (coll, algo, op) = {
            let c = &self.tenants[ti].cfg;
            (c.coll, c.algo, c.op)
        };
        let count = payload.len() as u32;
        for (frag_idx, frag_total, _off, chunk) in fragment(&payload) {
            let pkt = CollPacket {
                comm_id: CollPacket::make_comm_id(comm, epoch),
                comm_size: gsize as u16,
                coll_type: coll,
                algo_type: algo,
                node_type: node_role(algo, src - base, gsize),
                msg_type: mt,
                step,
                rank: (src - base) as u16,
                root: 0,
                operation: op,
                data_type: payload.dtype(),
                count,
                frag_idx,
                frag_total,
                tag,
                payload: chunk,
            };
            let frame = Frame::new(src, dst, FrameBody::Coll(pkt));
            self.transmit(src, dst, frame, ready);
        }
    }
}

/// Oracle comparison.  Integers must match exactly; floats allow the
/// association-order rounding every MPI implementation allows (the tree
/// algorithms fold in a different order than the oracle's left fold).
fn assert_payload_matches(got: &Payload, want: &Payload, rank: Rank, epoch: u32, series: &str) {
    assert_eq!(got.dtype(), want.dtype(), "rank {rank} epoch {epoch} dtype ({series})");
    assert_eq!(got.len(), want.len(), "rank {rank} epoch {epoch} length ({series})");
    match got.dtype() {
        Dtype::I32 => assert_eq!(
            got.to_i32(),
            want.to_i32(),
            "rank {rank} epoch {epoch}: scan result does not match oracle ({series})"
        ),
        Dtype::F32 => {
            for (i, (g, w)) in got.to_f32().iter().zip(want.to_f32().iter()).enumerate() {
                let tol = 1e-4f32.max(w.abs() * 1e-4);
                assert!(
                    (g - w).abs() <= tol,
                    "rank {rank} epoch {epoch} elem {i}: {g} vs oracle {w} ({series})"
                );
            }
        }
        Dtype::F64 => {
            for (i, (g, w)) in got.to_f64().iter().zip(want.to_f64().iter()).enumerate() {
                let tol = 1e-10f64.max(w.abs() * 1e-10);
                assert!(
                    (g - w).abs() <= tol,
                    "rank {rank} epoch {epoch} elem {i}: {g} vs oracle {w} ({series})"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, ExecPath};
    use crate::packet::{AlgoType, CollType};
    use crate::runtime::make_engine as make_compute;

    fn run_cfg(mut cfg: ExpConfig) -> RunMetrics {
        cfg.verify = true;
        cfg.iters = 20;
        cfg.warmup = 4;
        let compute = make_compute(EngineKind::Native, "artifacts");
        let mut cluster = Cluster::new(cfg, compute);
        cluster.run().expect("simulation must not deadlock")
    }

    fn base(algo: AlgoType, offloaded: bool) -> ExpConfig {
        let mut cfg = ExpConfig::default();
        cfg.algo = algo;
        cfg.path = if offloaded { ExecPath::Fpga } else { ExecPath::Sw };
        cfg.msg_bytes = 64;
        cfg
    }

    #[test]
    fn all_algorithms_verify_both_paths() {
        for algo in AlgoType::ALL {
            for offloaded in [false, true] {
                let m = run_cfg(base(algo, offloaded));
                let all = m.host_overall();
                assert_eq!(all.count(), 8 * 20, "{algo:?} offloaded={offloaded}");
                assert!(all.min_ns() > 0);
            }
        }
    }

    #[test]
    fn exscan_verifies() {
        for algo in AlgoType::ALL {
            let mut cfg = base(algo, true);
            cfg.coll = CollType::Exscan;
            run_cfg(cfg);
        }
    }

    #[test]
    fn nic_elapsed_only_on_offload_path() {
        let m_nf = run_cfg(base(AlgoType::RecursiveDoubling, true));
        assert_eq!(m_nf.nic_overall().count(), 8 * 20);
        let m_sw = run_cfg(base(AlgoType::RecursiveDoubling, false));
        assert_eq!(m_sw.nic_overall().count(), 0);
    }

    #[test]
    fn offload_overhead_visible_at_small_sizes() {
        // the 2-crossing overhead must make NF_rd latency exceed the pure
        // on-NIC time by at least the two fixed crossing costs.
        let m = run_cfg(base(AlgoType::RecursiveDoubling, true));
        let host = m.host_overall().avg_ns();
        let nic = m.nic_overall().avg_ns();
        let cost = crate::config::CostModel::default();
        assert!(
            host >= nic + (cost.offload_crossing_ns + cost.result_crossing_ns) as f64,
            "host {host} vs nic {nic}"
        );
    }

    #[test]
    fn offloaded_rd_beats_software_rd() {
        // the paper's headline: synchronizing algorithms win offloaded
        let nf = run_cfg(base(AlgoType::RecursiveDoubling, true)).host_overall().avg_ns();
        let sw = run_cfg(base(AlgoType::RecursiveDoubling, false)).host_overall().avg_ns();
        assert!(nf < sw, "NF_rd {nf} must beat sw_rd {sw}");
    }

    #[test]
    fn software_sequential_has_lowest_average() {
        // paper Fig. 4: sw sequential's pipelining yields the lowest avg
        let sw_seq = run_cfg(base(AlgoType::Sequential, false)).host_overall().avg_ns();
        let sw_rd = run_cfg(base(AlgoType::RecursiveDoubling, false)).host_overall().avg_ns();
        let nf_seq = run_cfg(base(AlgoType::Sequential, true)).host_overall().avg_ns();
        assert!(sw_seq < sw_rd, "sw_seq {sw_seq} vs sw_rd {sw_rd}");
        assert!(sw_seq < nf_seq, "sw_seq {sw_seq} vs NF_seq {nf_seq}");
    }

    #[test]
    fn determinism_same_seed_same_metrics() {
        let a = run_cfg(base(AlgoType::BinomialTree, true));
        let b = run_cfg(base(AlgoType::BinomialTree, true));
        assert_eq!(a.host_overall().avg_ns(), b.host_overall().avg_ns());
        assert_eq!(a.sim_ns, b.sim_ns);
        assert_eq!(a.total_frames(), b.total_frames());
    }

    #[test]
    fn different_seed_different_jitter() {
        let a = run_cfg(base(AlgoType::RecursiveDoubling, true));
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.seed = 12345;
        let b = run_cfg(cfg);
        // latencies shift with arrival jitter (min may coincide)
        assert_ne!(a.sim_ns, b.sim_ns);
    }

    #[test]
    fn large_messages_fragment_and_verify() {
        for algo in AlgoType::ALL {
            for offloaded in [false, true] {
                let mut cfg = base(algo, offloaded);
                cfg.msg_bytes = 8192; // ~6 fragments per message
                cfg.iters = 5;
                cfg.warmup = 1;
                let mut c = Cluster::new(
                    {
                        cfg.verify = true;
                        cfg
                    },
                    make_compute(EngineKind::Native, "artifacts"),
                );
                c.run().unwrap();
            }
        }
    }

    #[test]
    fn f64_and_max_op_verify() {
        let mut cfg = base(AlgoType::BinomialTree, true);
        cfg.dtype = crate::data::Dtype::F64;
        cfg.op = Op::Max;
        cfg.msg_bytes = 128;
        run_cfg(cfg);
    }

    #[test]
    fn late_rank_scenario_verifies_with_multicast() {
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.p = 4;
        cfg.late_rank = Some(1);
        cfg.late_delay_ns = 200_000;
        cfg.cost.start_jitter_ns = 0;
        run_cfg(cfg);
    }

    #[test]
    fn multicast_opt_taken_and_faster_for_late_rank() {
        let mk = |opt: bool| {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.p = 4;
            cfg.late_rank = Some(1);
            cfg.late_delay_ns = 500_000;
            cfg.cost.start_jitter_ns = 0;
            cfg.multicast_opt = opt;
            run_cfg(cfg)
        };
        let with = mk(true);
        let without = mk(false);
        assert!(with.multicasts > 0, "late rank must take the multicast path");
        assert_eq!(without.multicasts, 0);
        // one packet generation saved per multicast: the same frames hit
        // the wire, earlier.
        assert_eq!(with.total_frames(), without.total_frames());
        assert!(
            with.host_overall().avg_ns() < without.host_overall().avg_ns(),
            "multicast saves a packet generation: {} vs {}",
            with.host_overall().avg_ns(),
            without.host_overall().avg_ns()
        );
    }

    #[test]
    fn sequential_chain_no_forwarding() {
        let m = run_cfg(base(AlgoType::Sequential, true));
        assert_eq!(m.frames_forwarded.iter().sum::<u64>(), 0, "chain is 1-hop for seq");
    }

    #[test]
    fn topology_mismatch_forces_forwarding() {
        // sequential on a hypercube: ranks 3<->4 are 3 hops apart
        let mut cfg = base(AlgoType::Sequential, true);
        cfg.topology = "hypercube".into();
        let m = run_cfg(cfg);
        assert!(m.frames_forwarded.iter().sum::<u64>() > 0);
    }

    #[test]
    fn star_topology_verifies_and_uses_trunks() {
        // every flow crosses at least one switch: host NICs never forward
        // themselves, the switch layer carries everything
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.topology = "star:4".into();
        let m = run_cfg(cfg);
        assert_eq!(m.host_overall().count(), 8 * 20);
        assert_eq!(m.frames_forwarded.iter().sum::<u64>(), 0, "hosts are leaves");
        assert!(m.switch_frames_forwarded > 0, "switches carried the traffic");
        assert!(m.switch_frames_tx >= m.switch_frames_forwarded);
    }

    #[test]
    fn fattree_verifies_all_algorithms_and_paths() {
        for algo in AlgoType::ALL {
            for offloaded in [false, true] {
                let mut cfg = base(algo, offloaded);
                cfg.topology = "fattree".into();
                cfg.iters = 8;
                cfg.warmup = 2;
                cfg.verify = true;
                let compute = make_compute(EngineKind::Native, "artifacts");
                let mut cluster = Cluster::new(cfg, compute);
                let m = cluster.run().unwrap_or_else(|e| panic!("{algo:?} nf={offloaded}: {e}"));
                assert!(m.switch_frames_forwarded > 0, "{algo:?} nf={offloaded}");
            }
        }
    }

    #[test]
    fn switch_hop_cost_is_charged() {
        // same workload, slower switches -> strictly higher latency
        let mk = |switch_fwd_ns: u64| {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.topology = "fattree".into();
            cfg.cost.switch_fwd_ns = switch_fwd_ns;
            run_cfg(cfg).host_overall().avg_ns()
        };
        let fast = mk(100);
        let slow = mk(20_000);
        assert!(slow > fast, "switch forwarding must cost latency: {slow} vs {fast}");
    }

    #[test]
    fn concurrent_communicators_verify_independently() {
        // the paper SSVI comm_id feature: two disjoint 4-rank
        // communicators scanning simultaneously on the shared network
        for algo in AlgoType::ALL {
            for offloaded in [false, true] {
                let mut cfg = base(algo, offloaded);
                cfg.p = 8;
                cfg.tenants = 2;
                let m = run_cfg(cfg);
                assert_eq!(m.host_overall().count(), 8 * 20, "{algo:?} nf={offloaded}");
            }
        }
    }

    #[test]
    fn four_communicators_of_two() {
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.tenants = 4;
        run_cfg(cfg);
    }

    #[test]
    fn allreduce_and_barrier_end_to_end() {
        for algo in [AlgoType::RecursiveDoubling, AlgoType::BinomialTree] {
            for offloaded in [false, true] {
                let mut cfg = base(algo, offloaded);
                cfg.coll = CollType::Allreduce;
                run_cfg(cfg);
                let mut cfg = base(algo, offloaded);
                cfg.coll = CollType::Barrier;
                run_cfg(cfg);
            }
        }
    }

    #[test]
    fn allreduce_multicasts_down() {
        // SSIII-D: the tree allreduce down-phase uses the multicast
        // engine (one generation, fan-out to all children) — unlike scan
        let mut cfg = base(AlgoType::BinomialTree, true);
        cfg.coll = CollType::Allreduce;
        let m = run_cfg(cfg);
        assert!(m.multicasts > 0, "tree allreduce must multicast its down phase");
        let mut cfg = base(AlgoType::BinomialTree, true);
        cfg.coll = CollType::Scan;
        let m = run_cfg(cfg);
        assert_eq!(m.multicasts, 0, "scan down phase cannot multicast (unique prefixes)");
    }

    #[test]
    fn handler_vm_all_collectives_verify() {
        for coll in CollType::HANDLER_SET {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.path = ExecPath::Handler;
            cfg.coll = coll;
            let m = run_cfg(cfg);
            assert_eq!(m.host_overall().count(), 8 * 20, "{coll:?}");
            assert_eq!(m.nic_overall().count(), 8 * 20, "{coll:?} measures on-NIC time");
            assert!(m.handler_instrs > 0, "{coll:?} retired VM instructions");
        }
    }

    #[test]
    fn handler_values_equal_fixed_function_values() {
        // one collective end-to-end over the real network on both offload
        // paths: the result bytes must match exactly (latencies may not)
        for coll in [CollType::Scan, CollType::Exscan, CollType::Allreduce] {
            let run_path = |handler: bool| -> Vec<Payload> {
                let mut cfg = base(AlgoType::RecursiveDoubling, true);
                cfg.coll = coll;
                cfg.path = if handler { ExecPath::Handler } else { ExecPath::Fpga };
                cfg.verify = true;
                let contribs: Vec<Payload> =
                    (0..cfg.p).map(|r| Cluster::gen_payload(&cfg, r, 0)).collect();
                let compute = make_compute(EngineKind::Native, "artifacts");
                let (results, _) = Cluster::scan_once(cfg, compute, contribs).unwrap();
                results
            };
            let vm = run_path(true);
            let ff = run_path(false);
            for r in 0..8 {
                assert_eq!(vm[r].bytes(), ff[r].bytes(), "{coll:?} rank {r}");
            }
        }
    }

    #[test]
    fn handler_stalls_counted_for_late_ranks() {
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.path = ExecPath::Handler;
        cfg.p = 4;
        cfg.late_rank = Some(1);
        cfg.late_delay_ns = 200_000;
        cfg.cost.start_jitter_ns = 0;
        let m = run_cfg(cfg);
        assert!(m.handler_stalls > 0, "buffered packets park the handler");
    }

    #[test]
    fn handler_instruction_cost_is_charged() {
        let mk = |instr_cycles: u64| {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.path = ExecPath::Handler;
            cfg.cost.handler_instr_cycles = instr_cycles;
            run_cfg(cfg).host_overall().avg_ns()
        };
        let fast = mk(1);
        let slow = mk(100);
        assert!(slow > fast, "per-instruction cycles must cost latency: {slow} vs {fast}");
    }

    #[test]
    fn handler_on_fattree_and_concurrent_communicators() {
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.path = ExecPath::Handler;
        cfg.topology = "fattree".into();
        let m = run_cfg(cfg);
        assert!(m.switch_frames_forwarded > 0);

        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.path = ExecPath::Handler;
        cfg.tenants = 2;
        cfg.coll = CollType::Exscan;
        run_cfg(cfg);
    }

    #[test]
    fn offloaded_barrier_beats_software_barrier() {
        // the headline of the authors' companion work [6]
        let mk = |offloaded: bool| {
            let mut cfg = base(AlgoType::RecursiveDoubling, offloaded);
            cfg.coll = CollType::Barrier;
            run_cfg(cfg).host_overall().avg_ns()
        };
        let nf = mk(true);
        let sw = mk(false);
        assert!(nf < sw, "NF_barrier {nf} must beat sw_barrier {sw}");
    }

    #[test]
    fn trace_records_call_before_completion() {
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.iters = 3;
        cfg.warmup = 0;
        cfg.verify = true;
        let compute = make_compute(EngineKind::Native, "artifacts");
        let mut cluster = Cluster::new(cfg, compute);
        cluster.enable_trace(256);
        cluster.run().unwrap();
        use crate::trace::TraceKind;
        for r in 0..8 {
            let call = cluster.trace.first_of(r, TraceKind::HostCall).expect("call traced");
            let offl = cluster.trace.first_of(r, TraceKind::Offload).expect("offload traced");
            let done = cluster.trace.first_of(r, TraceKind::HostComplete).expect("done traced");
            assert!(call < offl && offl < done, "rank {r} milestone order");
        }
        let timeline = cluster.trace.timeline(8, 60);
        assert!(timeline.contains("r0 |"));
        // the span layer records wire serialization with real durations
        assert!(
            cluster.trace.iter().any(|e| e.kind == TraceKind::NicSend && e.end() > e.at),
            "NicSend spans must have duration"
        );
    }

    #[test]
    fn attribution_sums_and_leaves_schedule_untouched() {
        let mk = |attr: bool| {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.attribution = attr;
            run_cfg(cfg)
        };
        let off = mk(false);
        let on = mk(true);
        assert_eq!(off.sim_ns, on.sim_ns, "attribution must not move a single event");
        assert_eq!(off.total_frames(), on.total_frames());
        assert_eq!(off.host_overall().avg_ns(), on.host_overall().avg_ns());
        assert!(off.attribution.is_none());
        assert!(off.host_hist.is_empty());
        let a = on.attribution.expect("attribution populated when enabled");
        assert_eq!(a.components_sum(), a.latency_ns, "exact sum identity");
        assert!(a.latency_ns > 0);
        assert!(a.wire_ns > 0, "frames crossed wires");
        assert!(a.handler_exec_ns > 0, "NIC activations ran");
        // the latency histogram pools exactly the measured samples
        assert_eq!(on.host_hist.count(), on.host_overall().count());
    }

    #[test]
    fn attribution_covers_all_paths_and_recovery() {
        for path in [ExecPath::Sw, ExecPath::Fpga, ExecPath::Handler] {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.path = path;
            cfg.attribution = true;
            cfg.loss = 0.04;
            cfg.cost.max_retries = 8;
            let m = run_cfg(cfg);
            let a = m.attribution.expect("attribution populated");
            assert_eq!(a.components_sum(), a.latency_ns, "{path:?}: sum identity");
            assert!(a.compute_ns > 0, "{path:?}: combine folds happened");
            assert!(m.retransmits > 0, "{path:?}: the lossy run recovered");
        }
    }

    #[test]
    fn hpu_queueing_shows_up_in_attribution() {
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.path = ExecPath::Handler;
        cfg.cost.handler_instr_cycles = 2000;
        cfg.cost.hpus = 1;
        cfg.attribution = true;
        let m = run_cfg(cfg);
        let a = m.attribution.unwrap();
        assert_eq!(a.components_sum(), a.latency_ns);
        assert!(a.hpu_queue_ns > 0, "a single unit must park measured activations");
    }

    #[test]
    fn profile_counts_every_pop() {
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.iters = 5;
        cfg.warmup = 1;
        cfg.verify = true;
        let compute = make_compute(EngineKind::Native, "artifacts");
        let mut cluster = Cluster::new(cfg, compute);
        cluster.enable_profile();
        cluster.run().unwrap();
        let prof = cluster.profile().expect("profile enabled");
        assert_eq!(prof.counts.iter().sum::<u64>(), prof.pops);
        assert!(prof.counts[0] > 0, "host_start events popped");
        assert!(prof.counts[2] > 0, "nic_recv events popped");
        let table = prof.render();
        assert!(table.contains("host_start"));
        assert!(table.contains("total"));
    }

    #[test]
    fn hpu_saturation_queues_and_charges_delay() {
        // long handler activations (~0.5 ms each) guarantee overlapping
        // work at every card: the host request and the partner's step-0
        // packet land within one activation window.  One unit per card
        // must park the overlap; an unconstrained pool never does.
        let mk = |hpus: u64| {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.path = ExecPath::Handler;
            cfg.cost.handler_instr_cycles = 2000;
            cfg.cost.hpus = hpus;
            run_cfg(cfg)
        };
        let free = mk(0);
        assert_eq!(free.hpu_queued, 0, "unconstrained pool never parks");
        assert_eq!(free.hpu_queue_ns, 0);
        let one = mk(1);
        assert!(one.hpu_queued > 0, "a single unit must park overlapping activations");
        assert!(one.hpu_queue_ns > 0, "parked activations are charged queueing delay");
        assert!(
            one.host_overall().avg_ns() >= free.host_overall().avg_ns(),
            "queueing cannot make the run faster: {} vs {}",
            one.host_overall().avg_ns(),
            free.host_overall().avg_ns()
        );
    }

    #[test]
    fn hpus_do_not_affect_fixed_function_path() {
        // the bounded pool models handler execution units; the paper's
        // fixed-function datapath is dedicated silicon and bypasses it
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.cost.hpus = 1;
        let m = run_cfg(cfg);
        assert_eq!(m.hpu_queued, 0);
        assert_eq!(m.hpu_queue_ns, 0);
    }

    #[test]
    fn background_traffic_arrives_and_costs_latency() {
        let mk = |flows: usize| {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.bg_flows = flows;
            cfg.bg_msgs = 50;
            run_cfg(cfg)
        };
        let quiet = mk(0);
        assert_eq!(quiet.bg_frames_rx, 0);
        let noisy = mk(4);
        assert_eq!(noisy.bg_frames_rx, 4 * 50, "every injected frame must arrive");
        assert!(
            noisy.host_overall().avg_ns() >= quiet.host_overall().avg_ns(),
            "interference cannot speed up the collective"
        );
    }

    #[test]
    fn tenant_latency_recorded_and_fairness_near_one() {
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.tenants = 2;
        let m = run_cfg(cfg);
        assert_eq!(m.tenant_host.len(), 2);
        for t in &m.tenant_host {
            assert_eq!(t.count(), 4 * 20, "per-tenant samples = group ranks x iters");
            assert!(t.percentile_ns(99.0) >= t.percentile_ns(50.0));
        }
        let f = m.fairness();
        assert!(f > 0.8 && f <= 1.0, "identical tenants should be near-fair: {f}");
    }

    #[test]
    fn heterogeneous_session_verifies_under_interference() {
        // 4 ranks of offloaded RD scan + 4 ranks of software sequential
        // scan sharing one fat-tree with background flows, both
        // oracle-checked
        let mut fabric = ExpConfig::default().fabric();
        fabric.topology = "fattree".into();
        fabric.verify = true;
        fabric.bg_flows = 2;
        let mut w1 = ExpConfig::default().workload();
        w1.msg_bytes = 64;
        w1.iters = 10;
        w1.warmup = 2;
        let mut w2 = w1.clone();
        w2.path = ExecPath::Sw;
        w2.algo = AlgoType::Sequential;
        w2.msg_bytes = 256;
        let m = Session::on_fabric(fabric)
            .compute(make_compute(EngineKind::Native, "artifacts"))
            .tenant(4, w1)
            .tenant(4, w2)
            .run()
            .expect("heterogeneous session completes");
        assert_eq!(m.tenant_host.len(), 2);
        assert_eq!(m.tenant_host[0].count(), 4 * 10);
        assert_eq!(m.tenant_host[1].count(), 4 * 10);
        assert!(m.bg_frames_rx > 0);
    }

    #[test]
    fn fault_knobs_off_leave_schedule_byte_identical() {
        // with loss = 0 and no drop/crash/corrupt/reorder schedule the
        // whole failure stack must be completely inert: changing its
        // tuning knobs cannot move a single event, and no recovery or
        // crash metric may tick
        let mk = |timeout_ns: u64, max_retries: u32, probe_ns: u64, watchdog_ns: u64| {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.cost.timeout_ns = timeout_ns;
            cfg.cost.max_retries = max_retries;
            cfg.cost.probe_interval_ns = probe_ns;
            cfg.cost.watchdog_ns = watchdog_ns;
            // empty schedules are the quiet default, spelled explicitly
            cfg.crash_spec = String::new();
            cfg.corrupt_spec = String::new();
            cfg.reorder_spec = String::new();
            run_cfg(cfg)
        };
        let d = crate::config::CostModel::default();
        let a = mk(d.timeout_ns, 3, d.probe_interval_ns, d.watchdog_ns);
        let b = mk(999, 1, 77, 1);
        assert_eq!(a.sim_ns, b.sim_ns, "timers must not exist on a quiet plan");
        assert_eq!(a.total_frames(), b.total_frames());
        for m in [&a, &b] {
            assert_eq!(m.retransmits, 0);
            assert_eq!(m.timeouts_fired, 0);
            assert_eq!(m.recovery_ns, 0);
            assert_eq!(m.crashes, 0);
            assert_eq!(m.false_suspicions, 0);
            assert_eq!(m.detection_ns, 0);
            assert_eq!(m.reroutes, 0);
            assert_eq!(m.degraded_completions, 0);
        }
    }

    #[test]
    fn random_loss_recovers_on_every_path() {
        // 4% loss on every hop: all three execution paths must observe
        // drops, retransmit, and still bit-match the oracle (run_cfg
        // verifies).  max_retries is raised so a give-up is essentially
        // impossible at this seed/loss combination.
        for path in [ExecPath::Sw, ExecPath::Fpga, ExecPath::Handler] {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.path = path;
            cfg.loss = 0.04;
            cfg.cost.max_retries = 8;
            let m = run_cfg(cfg);
            assert!(m.retransmits > 0, "{path:?}: 4% loss over ~thousands of frames");
            assert!(m.timeouts_fired >= m.retransmits, "{path:?}: every resend needs a timer");
        }
    }

    #[test]
    fn scheduled_drop_is_recovered_deterministically() {
        // kill exactly the first frame on the 0->1 wire: whichever frame
        // that is (data or ack), recovery must fire and be charged
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.drop_spec = "0->1:1".into();
        let m = run_cfg(cfg);
        assert!(m.retransmits >= 1, "the dropped frame must be resent");
        assert!(m.timeouts_fired >= 1);
        assert!(m.recovery_ns > 0, "recovery latency must be attributed");
    }

    #[test]
    fn retry_exhaustion_fails_loudly_with_flow_identity() {
        // black-hole the 0->1 wire long enough to exhaust the retry
        // budget: the run must surface a named error, not hang until the
        // deadlock detector (or the test harness) gives up
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.p = 2;
        cfg.iters = 1;
        cfg.warmup = 0;
        cfg.verify = false;
        cfg.cost.max_retries = 2;
        cfg.drop_spec =
            (1..=12).map(|n| format!("0->1:{n}")).collect::<Vec<_>>().join(",");
        let compute = make_compute(EngineKind::Native, "artifacts");
        let mut cluster = Cluster::new(cfg, compute);
        let err = cluster.run().expect_err("give-up must be an error, not a deadlock");
        let msg = err.to_string();
        assert!(msg.contains("recovery failed"), "{msg}");
        assert!(msg.contains("rank"), "{msg}");
        assert!(msg.contains("epoch"), "{msg}");
    }

    #[test]
    fn rank_crash_mid_run_degrades_and_survivors_complete() {
        // rank 3 fail-stops at the top of epoch 10: its silence must be
        // detected through ack give-up, the group must shrink, and every
        // stuck survivor epoch must complete with the survivor-oracle
        // value instead of hanging
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.crash_spec = "rank:3@epoch:10".into();
        let m = run_cfg(cfg);
        assert_eq!(m.crashes, 1, "exactly the scheduled crash");
        assert_eq!(m.false_suspicions, 0, "nobody healthy was evicted");
        assert!(m.detection_ns > 0, "detection latency is measured from death to verdict");
        assert!(m.degraded_completions >= 1, "stuck survivor epochs complete shrunk");
        assert!(m.reroutes >= 1, "the dead rank is excluded from the route table");
    }

    #[test]
    fn lone_survivor_completes_its_own_prefix() {
        // p=2 and the partner dies before its first contribution: the
        // survivor's inclusive scan degenerates to its own payload, and
        // the run must terminate cleanly with one degraded completion
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.p = 2;
        cfg.crash_spec = "rank:1@epoch:0".into();
        let m = run_cfg(cfg);
        assert_eq!(m.crashes, 1);
        assert_eq!(m.degraded_completions, 1, "only epoch 0 was in flight");
        assert_eq!(m.false_suspicions, 0);
    }

    #[test]
    fn switch_crash_on_fattree_reroutes_and_completes() {
        // agg(0,1) (switch index 3 in pod-major numbering) dies mid-run:
        // pod 0 still has agg(0,0), so BFS reroutes around the corpse
        // and every rank finishes every iteration
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.topology = "fattree".into();
        cfg.crash_spec = "switch:3@ns:300000".into();
        let m = run_cfg(cfg);
        assert_eq!(m.crashes, 1, "the switch death is a crash");
        assert!(m.reroutes >= 1, "routes were rebuilt around the dead switch");
        assert_eq!(m.degraded_completions, 0, "no rank died — no degradation");
        assert_eq!(m.host_overall().count(), 8 * 20, "all iterations complete");
    }

    #[test]
    fn star_trunk_death_is_a_named_partition() {
        // leaf switch 0 of star:4 carries hosts 0..4: its death cuts
        // them off from the rest, which must surface as a structured
        // partition error, never a hang
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.topology = "star:4".into();
        cfg.crash_spec = "switch:0@ns:200000".into();
        cfg.verify = false;
        let compute = make_compute(EngineKind::Native, "artifacts");
        let mut cluster = Cluster::new(cfg, compute);
        let err = cluster.run().expect_err("a partition must be an error");
        let msg = err.to_string();
        assert!(msg.contains("partition"), "{msg}");
        assert!(msg.contains("star"), "{msg}");
    }

    #[test]
    fn corrupt_frames_fail_crc_and_are_recovered() {
        // mangle exactly the first frame on the 0->1 wire: the receiver's
        // CRC check must discard it pre-ack and the retransmit path must
        // recover it like a drop (run_cfg verifies the values)
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.corrupt_spec = "0->1:1".into();
        let m = run_cfg(cfg);
        assert!(m.retransmits >= 1, "the corrupted frame must be resent");
        assert!(m.recovery_ns > 0, "recovery latency must be attributed");
    }

    #[test]
    fn reordered_frames_still_verify() {
        // park the first frame on the 0->1 wire long enough for its
        // successors to overtake: dedup + engine state machines must
        // still produce oracle-exact values (run_cfg verifies)
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.reorder_spec = "0->1:1".into();
        let quiet = run_cfg(base(AlgoType::RecursiveDoubling, true));
        let held = run_cfg(cfg);
        assert!(held.sim_ns != quiet.sim_ns, "the hold must actually move the schedule");
    }

    #[test]
    fn false_suspicion_evicts_live_rank_and_terminates() {
        // a black-holed wire under a crash-scheduled plan: the give-up
        // verdict wrongly convicts the (alive) silent peer.  The group
        // must treat the eviction as fail-stop — count it as a false
        // suspicion, shrink, and terminate — because ULFM-style
        // agreement cannot distinguish dead from unreachable.
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.p = 2;
        cfg.crash_spec = "rank:1@epoch:18".into(); // arms detection; never reached
        cfg.drop_spec = (1..=12).map(|n| format!("0->1:{n}")).collect::<Vec<_>>().join(",");
        cfg.cost.max_retries = 2;
        let m = run_cfg(cfg);
        assert_eq!(m.false_suspicions, 1, "the live rank was wrongly convicted");
        assert_eq!(m.crashes, 0, "nobody actually died");
        assert!(m.degraded_completions >= 1, "the survivor still completes");
    }

    #[test]
    fn watchdog_converts_undetectable_stall_to_named_error() {
        // a retry budget so deep that give-up (and therefore suspicion)
        // would take longer than anyone is willing to wait: the watchdog
        // must convert the stall into a named error instead of a hang
        let mut cfg = base(AlgoType::RecursiveDoubling, true);
        cfg.p = 2;
        cfg.iters = 1;
        cfg.warmup = 0;
        cfg.verify = false;
        cfg.crash_spec = "rank:1@epoch:0".into();
        cfg.cost.max_retries = 60;
        cfg.cost.watchdog_ns = 5_000_000;
        let compute = make_compute(EngineKind::Native, "artifacts");
        let mut cluster = Cluster::new(cfg, compute);
        let err = cluster.run().expect_err("the stall must be an error, not a hang");
        assert!(err.to_string().contains("watchdog"), "{err}");
    }

    #[test]
    fn trunk_degradation_slows_switch_topologies_only() {
        let mk = |topology: &str, degrade: f64| {
            let mut cfg = base(AlgoType::RecursiveDoubling, true);
            cfg.topology = topology.into();
            cfg.trunk_degrade = degrade;
            run_cfg(cfg)
        };
        // star: every flow crosses the switch, whose uplinks degrade
        let slow = mk("star:4", 4.0);
        let fast = mk("star:4", 1.0);
        assert!(
            slow.host_overall().avg_ns() > fast.host_overall().avg_ns(),
            "degraded trunks must cost latency: {} vs {}",
            slow.host_overall().avg_ns(),
            fast.host_overall().avg_ns()
        );
        // direct wiring has no switch trunks: the knob must be inert
        let a = mk("auto", 1.0);
        let b = mk("auto", 4.0);
        assert_eq!(a.sim_ns, b.sim_ns, "no trunks to degrade on direct wiring");
    }

    #[test]
    fn tenant_sizes_must_sum_to_fabric() {
        let fabric = ExpConfig::default().fabric(); // p = 8
        let w = ExpConfig::default().workload();
        let err = Cluster::with_tenants(
            &fabric,
            &[(4, w.clone()), (2, w)],
            make_compute(EngineKind::Native, "artifacts"),
        )
        .unwrap_err();
        assert!(err.to_string().contains("sum"), "{err}");
    }
}
