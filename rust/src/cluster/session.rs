//! The workload-oriented front door: build a multi-tenant run as
//! "fabric + tenants" instead of one flat [`ExpConfig`].
//!
//! ```text
//! Session::on_fabric(fabric)
//!     .compute(engine)
//!     .tenant(4, scan_workload)      // ranks 0..4
//!     .tenant(4, allreduce_workload) // ranks 4..8
//!     .run()?
//! ```
//!
//! Tenants claim contiguous rank ranges in declaration order and must
//! cover the fabric exactly.  With no tenants declared, one default
//! workload spans the whole fabric — making `Session` a superset of the
//! old `Cluster::new` + `run` flow.  [`Session::scan_once`] is the
//! application-style entry (one collective over caller-provided
//! contributions); [`crate::cluster::Cluster::scan_once`] is now a thin
//! wrapper over it.

use std::rc::Rc;

use anyhow::{ensure, Result};

use crate::config::{FabricConfig, WorkloadSpec};
use crate::data::Payload;
use crate::metrics::RunMetrics;
use crate::runtime::Compute;

use super::Cluster;

pub struct Session {
    fabric: FabricConfig,
    compute: Option<Rc<dyn Compute>>,
    tenants: Vec<(usize, WorkloadSpec)>,
}

impl Session {
    /// Start describing a run over `fabric`.
    pub fn on_fabric(fabric: FabricConfig) -> Session {
        Session { fabric, compute: None, tenants: Vec::new() }
    }

    /// Use this compute engine (defaults to the fabric's configured
    /// engine kind with the standard artifact directory).
    pub fn compute(mut self, compute: Rc<dyn Compute>) -> Session {
        self.compute = Some(compute);
        self
    }

    /// Add one tenant over the next `ranks` global ranks.
    pub fn tenant(mut self, ranks: usize, spec: WorkloadSpec) -> Session {
        self.tenants.push((ranks, spec));
        self
    }

    /// Construct the cluster (validating every tenant against its own
    /// group) without running it — callers that want tracing or custom
    /// driving use this.
    pub fn build(self) -> Result<Cluster> {
        let compute = match self.compute {
            Some(c) => c,
            None => crate::runtime::make_engine(self.fabric.engine, "artifacts"),
        };
        let tenants = if self.tenants.is_empty() {
            vec![(self.fabric.p, WorkloadSpec::default())]
        } else {
            self.tenants
        };
        Cluster::with_tenants(&self.fabric, &tenants, compute)
    }

    /// Build and run the full benchmark loop (every tenant's warmup +
    /// iters), returning the pooled metrics.
    pub fn run(self) -> Result<RunMetrics> {
        self.build()?.run()
    }

    /// Application entry point: run ONE collective per tenant over
    /// caller-provided per-rank contributions (global rank order) and
    /// return each rank's result.  Forces every tenant to a single
    /// unmeasured-warmup-free iteration and takes each tenant's message
    /// size from its first rank's contribution.
    pub fn scan_once(mut self, contributions: Vec<Payload>) -> Result<(Vec<Payload>, RunMetrics)> {
        if self.tenants.is_empty() {
            self.tenants.push((self.fabric.p, WorkloadSpec::default()));
        }
        let total: usize = self.tenants.iter().map(|(n, _)| n).sum();
        ensure!(
            contributions.len() == total,
            "one contribution per rank: got {}, tenants cover {total}",
            contributions.len()
        );
        let mut base = 0;
        for (i, (size, spec)) in self.tenants.iter_mut().enumerate() {
            spec.iters = 1;
            spec.warmup = 0;
            spec.msg_bytes = contributions[base].byte_len();
            for r in base..base + *size {
                ensure!(
                    contributions[r].dtype() == spec.dtype,
                    "rank {r} contribution dtype does not match tenant {i}"
                );
                ensure!(
                    contributions[r].byte_len() == spec.msg_bytes,
                    "rank {r} contribution size differs within tenant {i}"
                );
            }
            base += *size;
        }
        let mut cluster = self.build()?;
        cluster.injected = Some(contributions);
        let metrics = cluster.run()?;
        let results = cluster
            .results
            .iter()
            .cloned()
            .map(|r| r.expect("every rank completed"))
            .collect();
        Ok((results, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, ExpConfig};
    use crate::runtime::make_engine as make_compute;

    #[test]
    fn session_scan_once_matches_cluster_scan_once() {
        // the wrapper and the builder must agree bit-for-bit
        let mut cfg = ExpConfig::default();
        cfg.msg_bytes = 64;
        cfg.verify = true;
        let contribs: Vec<Payload> =
            (0..cfg.p).map(|r| Cluster::gen_payload(&cfg, r, 0)).collect();
        let (via_wrapper, _) = Cluster::scan_once(
            cfg.clone(),
            make_compute(EngineKind::Native, "artifacts"),
            contribs.clone(),
        )
        .unwrap();
        let (via_session, _) = Session::on_fabric(cfg.fabric())
            .compute(make_compute(EngineKind::Native, "artifacts"))
            .tenant(cfg.p, cfg.workload())
            .scan_once(contribs)
            .unwrap();
        for r in 0..cfg.p {
            assert_eq!(via_wrapper[r].bytes(), via_session[r].bytes(), "rank {r}");
        }
    }

    #[test]
    fn session_defaults_to_single_tenant() {
        // no .tenant() call: one default workload spans the fabric
        let mut fabric = ExpConfig::default().fabric();
        fabric.verify = true;
        let cfg = ExpConfig::default();
        let contribs: Vec<Payload> =
            (0..fabric.p).map(|r| Cluster::gen_payload(&cfg, r, 0)).collect();
        let (results, m) = Session::on_fabric(fabric).scan_once(contribs).unwrap();
        assert_eq!(results.len(), 8);
        assert_eq!(m.tenant_host.len(), 1);
        assert_eq!(m.tenant_host[0].count(), 8);
    }

    #[test]
    fn session_rejects_uncovered_ranks() {
        let fabric = ExpConfig::default().fabric(); // p = 8
        let w = ExpConfig::default().workload();
        assert!(Session::on_fabric(fabric).tenant(6, w).run().is_err());
    }
}
