//! Cluster-level integration: resource limits, flow-control failure
//! injection, topology penalties, and the application API.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::data::{Dtype, Op, Payload};
use nfscan::packet::{AlgoType, CollType};
use nfscan::runtime::make_engine;

fn native() -> Rc<dyn nfscan::runtime::Compute> {
    make_engine(EngineKind::Native, "artifacts")
}

#[test]
fn scan_once_application_api() {
    let mut cfg = ExpConfig::default();
    cfg.algo = AlgoType::BinomialTree;
    cfg.verify = true;
    let contributions: Vec<Payload> =
        (0..8).map(|r| Payload::from_i32(&[r + 1, 2 * (r + 1)])).collect();
    let (results, metrics) = Cluster::scan_once(cfg, native(), contributions).unwrap();
    assert_eq!(results[0].to_i32(), vec![1, 2]);
    assert_eq!(results[7].to_i32(), vec![36, 72]);
    assert_eq!(metrics.host_overall().count(), 8);
}

#[test]
fn exscan_once_rank0_gets_identity() {
    let mut cfg = ExpConfig::default();
    cfg.coll = CollType::Exscan;
    cfg.op = Op::Prod;
    let contributions: Vec<Payload> = (0..8).map(|r| Payload::from_i32(&[r + 2])).collect();
    let (results, _) = Cluster::scan_once(cfg, native(), contributions).unwrap();
    assert_eq!(results[0].to_i32(), vec![1], "prod identity");
    assert_eq!(results[1].to_i32(), vec![2]);
    assert_eq!(results[3].to_i32(), vec![2 * 3 * 4]);
}

#[test]
#[should_panic(expected = "flow control failed")]
fn ack_disabled_overflows_nic_buffers() {
    // failure injection: the paper's SSIII-B ACK removed -> upstream
    // ranks run ahead until a card's engine table / single buffer
    // overflows.  The model asserts instead of silently dropping.
    let mut cfg = ExpConfig::default();
    cfg.algo = AlgoType::Sequential;
    cfg.path = ExecPath::Fpga;
    cfg.ack_enabled = false;
    cfg.iters = 400;
    cfg.warmup = 0;
    let mut cluster = Cluster::new(cfg, native());
    let _ = cluster.run();
}

#[test]
fn topology_mismatch_costs_latency() {
    // sequential on its natural chain vs forced onto a hypercube:
    // multi-hop forwarding must cost measurable latency.
    let run = |topology: &str| {
        let mut cfg = ExpConfig::default();
        cfg.algo = AlgoType::Sequential;
        cfg.path = ExecPath::Fpga;
        cfg.topology = topology.into();
        cfg.iters = 50;
        cfg.warmup = 8;
        cfg.verify = true;
        let mut cluster = Cluster::new(cfg, native());
        cluster.run().unwrap()
    };
    let chain = run("chain");
    let cube = run("hypercube");
    assert_eq!(chain.frames_forwarded.iter().sum::<u64>(), 0);
    assert!(cube.frames_forwarded.iter().sum::<u64>() > 0);
    assert!(
        cube.host_overall().avg_ns() > chain.host_overall().avg_ns(),
        "forwarding penalty: cube {} vs chain {}",
        cube.host_overall().avg_ns(),
        chain.host_overall().avg_ns()
    );
}

#[test]
fn algorithm_selection_policy_is_sane_end_to_end() {
    // the policy must pick the fastest measured algorithm per situation
    use nfscan::offload::select_algorithm;
    let measure = |algo: AlgoType, msg: usize| {
        let mut cfg = ExpConfig::default();
        cfg.algo = algo;
        cfg.path = ExecPath::Fpga;
        cfg.msg_bytes = msg;
        cfg.iters = 60;
        cfg.warmup = 8;
        let mut cluster = Cluster::new(cfg, native());
        cluster.run().unwrap().host_overall().avg_ns()
    };
    // hypercube, small message: policy says recursive doubling
    let topo = nfscan::net::Topology::hypercube(8);
    assert_eq!(select_algorithm(&topo, 64, 8), AlgoType::RecursiveDoubling);
    // hypercube, large message: policy says binomial — check it measures
    // faster than rd at that size
    assert_eq!(select_algorithm(&topo, 16384, 8), AlgoType::BinomialTree);
    let rd = measure(AlgoType::RecursiveDoubling, 16384);
    let bin = measure(AlgoType::BinomialTree, 16384);
    assert!(bin < rd, "binomial {bin} must beat rd {rd} at 16KB");
}

#[test]
fn all_dtypes_and_ops_verify_offloaded() {
    for dtype in Dtype::ALL {
        for op in Op::ALL {
            if !op.valid_for(dtype) {
                continue;
            }
            let mut cfg = ExpConfig::default();
            cfg.algo = AlgoType::RecursiveDoubling;
            cfg.path = ExecPath::Fpga;
            cfg.dtype = dtype;
            cfg.op = op;
            cfg.msg_bytes = 16 * dtype.size();
            cfg.iters = 5;
            cfg.warmup = 1;
            cfg.verify = true;
            let mut cluster = Cluster::new(cfg, native());
            cluster.run().unwrap_or_else(|e| panic!("{dtype:?}/{op:?}: {e}"));
        }
    }
}

#[test]
fn seq_supports_non_power_of_two() {
    for p in [3usize, 5, 7, 12] {
        let mut cfg = ExpConfig::default();
        cfg.p = p;
        cfg.algo = AlgoType::Sequential;
        cfg.path = ExecPath::Fpga;
        cfg.iters = 10;
        cfg.warmup = 2;
        cfg.verify = true;
        let mut cluster = Cluster::new(cfg, native());
        cluster.run().unwrap();
    }
}

#[test]
fn engine_table_stays_bounded_under_pipelining() {
    // back-to-back scans for a long stretch: the per-card engine table
    // must stay within the hardware cap (checked inside the NIC on every
    // activation — this run passing IS the assertion).
    for algo in AlgoType::ALL {
        let mut cfg = ExpConfig::default();
        cfg.algo = algo;
        cfg.path = ExecPath::Fpga;
        cfg.iters = 300;
        cfg.warmup = 0;
        cfg.cost.start_jitter_ns = 50_000; // heavy skew
        let mut cluster = Cluster::new(cfg, native());
        cluster.run().unwrap_or_else(|e| panic!("{algo:?}: {e}"));
    }
}

#[test]
fn warmup_iterations_are_not_measured() {
    let mut cfg = ExpConfig::default();
    cfg.iters = 10;
    cfg.warmup = 90;
    let mut cluster = Cluster::new(cfg, native());
    let m = cluster.run().unwrap();
    assert_eq!(m.host_overall().count(), 8 * 10);
    assert_eq!(m.nic_overall().count(), 8 * 10);
}
