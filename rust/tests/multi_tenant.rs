//! Multi-tenant fabric integration: the tenants grid axis must keep the
//! sweep byte-deterministic under any worker count, the per-tenant
//! artifact fields must be populated, and concurrent tenants must stay
//! oracle-correct under background interference and a bounded HPU pool.

use std::path::PathBuf;
use std::rc::Rc;

use nfscan::cluster::Session;
use nfscan::config::{EngineKind, ExecPath, ExpConfig, WorkloadSpec};
use nfscan::metrics::json::Json;
use nfscan::runtime::make_engine;
use nfscan::sweep::{run_grid, GridSpec};

/// Tenants axis crossed with both offload flavors, plus saturated HPUs
/// and background traffic — the most scheduler-dependent grid we have.
const TENANTS_GRID: &str = r#"
    [grid]
    name = "tenants"
    sizes = [64]
    tenants = [1, 2, 4]
    series = ["NF_rd", "handler:scan"]

    [run]
    p = 8
    iters = 12
    warmup = 2
    seed = 7
    bg_flows = 4
    bg_msgs = 30

    [cost]
    hpus = 1
"#;

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nfscan_mt_{tag}_{}", std::process::id()))
}

#[test]
fn tenants_sweep_bytes_identical_for_jobs_1_and_4() {
    let spec = GridSpec::from_toml(TENANTS_GRID).unwrap();
    let d1 = scratch("j1");
    let d4 = scratch("j4");
    let files1 = run_grid(&spec, 1, "artifacts").unwrap().write_artifacts(&d1).unwrap();
    let files4 = run_grid(&spec, 4, "artifacts").unwrap().write_artifacts(&d4).unwrap();
    assert!(!files1.is_empty());
    for (a, b) in files1.iter().zip(files4.iter()) {
        assert_eq!(
            std::fs::read(a).unwrap(),
            std::fs::read(b).unwrap(),
            "{} differs between --jobs 1 and --jobs 4",
            a.file_name().unwrap().to_string_lossy()
        );
    }
    std::fs::remove_dir_all(&d1).ok();
    std::fs::remove_dir_all(&d4).ok();
}

#[test]
fn tenants_sweep_reports_per_tenant_percentiles_and_fairness() {
    let spec = GridSpec::from_toml(TENANTS_GRID).unwrap();
    let report = run_grid(&spec, 4, "artifacts").unwrap();
    assert_eq!(report.jobs.len(), 6, "2 series x 3 tenants x 1 size");
    for job in &report.jobs {
        assert_eq!(job.tenant_p50_us.len(), job.tenants, "one p50 per tenant");
        assert_eq!(job.tenant_p99_us.len(), job.tenants, "one p99 per tenant");
        for (p50, p99) in job.tenant_p50_us.iter().zip(job.tenant_p99_us.iter()) {
            assert!(*p50 > 0.0 && p99 >= p50, "p50 {p50} p99 {p99}");
        }
        assert!(
            job.fairness > 0.0 && job.fairness <= 1.0 + 1e-12,
            "Jain index out of range: {}",
            job.fairness
        );
        assert!(job.bg_frames > 0, "background traffic must be simulated");
    }
    // a single homogeneous tenant is perfectly fair by definition
    let single = report.jobs.iter().find(|j| j.tenants == 1).unwrap();
    assert!((single.fairness - 1.0).abs() < 1e-12);

    // the new fields survive a JSON round trip with the same bytes
    let doc = report.to_json().pretty();
    let parsed = Json::parse(&doc).unwrap();
    let jobs = parsed.get("jobs").unwrap().as_arr().unwrap();
    assert_eq!(jobs[0].get("tenants").unwrap().as_u64(), Some(1));
    assert!(jobs.last().unwrap().get("fairness").unwrap().as_f64().is_some());
}

#[test]
fn pre_tenant_artifacts_still_parse() {
    // artifacts written before the tenants axis existed have none of the
    // per-tenant fields; loading them must default to a single tenant
    let legacy = r#"{
        "index": 0, "series": "NF_rd", "topology": "auto", "p": 8,
        "msg_bytes": 64, "seed": 1,
        "host": {"count": 2, "sum_ns": 100, "min_ns": 40, "max_ns": 60},
        "nic": {"count": 0, "sum_ns": 0, "min_ns": 0, "max_ns": 0},
        "total_frames": 9, "switch_frames": 0,
        "multicasts": 0, "sim_ns": 5
    }"#;
    let job = nfscan::sweep::JobResult::from_json(&Json::parse(legacy).unwrap()).unwrap();
    assert_eq!(job.tenants, 1);
    assert!(job.tenant_p50_us.is_empty());
    assert_eq!(job.fairness, 1.0);
    assert_eq!(job.bg_frames, 0);
}

#[test]
fn concurrent_tenants_verify_against_oracle_under_interference() {
    // two tenants on different datapaths, saturated HPUs, background
    // flows: with verify on, every iteration of every tenant is checked
    // against the reduction oracle inside the cluster — the run
    // completing IS the assertion.
    let mut fabric = ExpConfig::default().fabric();
    fabric.topology = "fattree".into();
    fabric.verify = true;
    fabric.bg_flows = 3;
    fabric.bg_msgs = 40;
    fabric.cost.hpus = 1;

    let mut handler = WorkloadSpec::default();
    handler.path = ExecPath::Handler;
    handler.msg_bytes = 64;
    handler.iters = 8;
    handler.warmup = 2;

    let mut sw = WorkloadSpec::default();
    sw.path = ExecPath::Sw;
    sw.msg_bytes = 256;
    sw.iters = 8;
    sw.warmup = 2;

    let m = Session::on_fabric(fabric)
        .compute(make_engine(EngineKind::Native, "artifacts"))
        .tenant(4, handler)
        .tenant(4, sw)
        .run()
        .unwrap();
    assert_eq!(m.tenant_host.len(), 2);
    for t in &m.tenant_host {
        assert_eq!(t.count(), 4 * 8, "4 ranks x 8 measured iterations");
    }
    assert!(m.bg_frames_rx > 0);
    assert!(m.hpu_queued > 0, "hpus = 1 must queue handler activations");
    let fairness = m.fairness();
    assert!(fairness > 0.0 && fairness <= 1.0 + 1e-12, "{fairness}");
}

#[test]
fn single_tenant_unconstrained_pool_matches_legacy_run() {
    // tenants = 1 + hpus = 0 must reproduce the exact event stream of
    // the pre-tenant cluster: same samples, same frame counts
    let mut cfg = ExpConfig::default();
    cfg.path = ExecPath::Handler;
    cfg.msg_bytes = 64;
    cfg.iters = 20;
    cfg.warmup = 4;
    let run = |cfg: &ExpConfig| {
        let compute: Rc<dyn nfscan::runtime::Compute> =
            make_engine(EngineKind::Native, "artifacts");
        let mut cluster = nfscan::cluster::Cluster::new(cfg.clone(), compute);
        cluster.run().unwrap()
    };
    let a = run(&cfg);
    let mut with_pool = cfg.clone();
    with_pool.cost.hpus = 0; // explicit default: unconstrained
    let b = run(&with_pool);
    assert_eq!(a.host_overall().avg_ns(), b.host_overall().avg_ns());
    assert_eq!(a.total_frames(), b.total_frames());
    assert_eq!(a.hpu_queued, 0);
    assert_eq!(b.hpu_queued, 0);
}
