//! Negative-program corpus for the static verifier: one *minimal*
//! ill-formed image per invariant class, each asserting the specific
//! reject reason (by its stable class name AND the site it points at).
//!
//! These are the verifier's contract tests: if a refactor of the
//! abstract interpreter silently stops catching one of these classes,
//! the corresponding dynamic assert becomes the only line of defense
//! again — exactly the regression PR 6 exists to prevent.

use nfscan::nic::verify::{verify, RejectReason, LOOP_BOUND, MAX_P, MAX_ROUNDS};
use nfscan::nic::vm::{AluOp, Asm, EnvVal, Instr, Program, MAX_STEPS, SCRATCH_SLOTS};

/// Verify a program that must be rejected; return its findings.
fn rejects(prog: &Program) -> Vec<RejectReason> {
    match verify(prog) {
        Ok(report) => panic!(
            "{} must be rejected, but verified with bounds {}/{}",
            prog.name, report.on_request_bound, report.on_packet_bound
        ),
        Err(reasons) => reasons,
    }
}

fn has_class(reasons: &[RejectReason], class: &str) -> bool {
    reasons.iter().any(|r| r.class() == class)
}

#[test]
fn uninit_read_on_one_path() {
    // r0 is written only on the taken branch; the fall-through path
    // reaches the Emit-free read of r0 with it still uninitialized.
    // Path-sensitivity matters: every straight-line prefix is fine.
    let mut a = Asm::new();
    let entry = a.label();
    let skip = a.label();
    a.bind(entry);
    a.env(1, EnvVal::Rank);
    a.jz(1, skip); // rank == 0: skip the init
    a.imm(0, 7);
    a.bind(skip);
    a.alu(AluOp::Add, 2, 0, 1); // r0 uninit when rank == 0
    a.halt();
    let prog = a.finish("neg-uninit", entry, entry);
    let rs = rejects(&prog);
    assert!(has_class(&rs, "uninit-read"), "{rs:?}");
    // the finding must name the faulting register, not just the pc
    assert!(
        rs.iter().any(|r| matches!(r, RejectReason::UninitRead { reg: 0, .. })),
        "{rs:?}"
    );
}

#[test]
fn scratch_index_not_provably_in_bounds() {
    // slot = rank + SCRATCH_SLOTS - 1: in range only for rank == 0, and
    // the program never guards it — the interval [63, 63 + MAX_P - 1]
    // is not within [0, 64).
    let mut a = Asm::new();
    let entry = a.label();
    a.bind(entry);
    a.env(0, EnvVal::Rank);
    a.imm(1, SCRATCH_SLOTS as i64 - 1);
    a.alu(AluOp::Add, 2, 0, 1);
    a.imm(3, 5);
    a.st(2, 3);
    a.halt();
    let prog = a.finish("neg-oob", entry, entry);
    let rs = rejects(&prog);
    assert!(has_class(&rs, "scratch-oob"), "{rs:?}");
    assert!(
        rs.iter().any(|r| matches!(
            r,
            RejectReason::ScratchOob { hi, .. } if *hi >= SCRATCH_SLOTS as i64
        )),
        "{rs:?}"
    );
}

#[test]
fn missing_halt_falls_off_the_end() {
    let prog = Program {
        name: "neg-nohalt",
        code: vec![Instr::Imm { dst: 0, val: 1 }, Instr::Mov { dst: 1, src: 0 }],
        on_request: 0,
        on_packet: 0,
    };
    let rs = rejects(&prog);
    assert!(has_class(&rs, "missing-halt"), "{rs:?}");
    assert!(rs.iter().any(|r| matches!(r, RejectReason::MissingHalt { pc: 1 })), "{rs:?}");
}

#[test]
fn inescapable_cycle_never_terminates() {
    // jz can exit in principle, but its target re-enters the loop: no
    // Halt/Drop is reachable from the cycle at all
    let mut a = Asm::new();
    let entry = a.label();
    let head = a.label();
    a.bind(entry);
    a.imm(0, 1);
    a.bind(head);
    a.alu(AluOp::Add, 0, 0, 0);
    a.jz(0, head);
    a.jmp(head);
    let prog = a.finish("neg-noterm", entry, entry);
    let rs = rejects(&prog);
    assert!(has_class(&rs, "no-termination"), "{rs:?}");
}

#[test]
fn budget_blowup_via_oversized_loop_body() {
    // one RD-style loop whose ~300-instruction body pushes
    // body x LOOP_BOUND past MAX_STEPS: each back-edge is granted
    // LOOP_BOUND trips, so the bound is ~301 x 17 > 4096
    let mut a = Asm::new();
    let entry = a.label();
    a.bind(entry);
    a.imm(0, 0);
    a.imm(1, 1);
    let head = a.label();
    a.bind(head);
    for _ in 0..300 {
        a.alu(AluOp::Add, 0, 0, 1);
    }
    a.env(2, EnvVal::P);
    a.alu(AluOp::Lt, 3, 0, 2);
    a.jnz(3, head);
    a.halt();
    let prog = a.finish("neg-budget", entry, entry);
    let rs = rejects(&prog);
    assert!(has_class(&rs, "budget"), "{rs:?}");
    let bound = rs
        .iter()
        .find_map(|r| match r {
            RejectReason::BudgetExceeded { bound, .. } => Some(*bound),
            _ => None,
        })
        .expect("budget finding carries its bound");
    assert!(bound > MAX_STEPS, "reported bound {bound} must exceed {MAX_STEPS}");
    assert!(
        bound >= 300 * LOOP_BOUND,
        "bound {bound} must reflect body x per-back-edge trips"
    );
}

#[test]
fn dtype_mismatch_combine_over_integers() {
    // Combine drives the shared dtype x op datapath; an integer operand
    // can never be valid, so this is a static fact, not a maybe
    let mut a = Asm::new();
    let entry = a.label();
    a.bind(entry);
    a.ldpkt(0);
    a.imm(1, 3);
    a.combine(2, 0, 1); // payload (op) integer
    a.halt();
    let prog = a.finish("neg-dtype", entry, entry);
    let rs = rejects(&prog);
    assert!(has_class(&rs, "dtype-mismatch"), "{rs:?}");
    assert!(
        rs.iter().any(|r| matches!(
            r,
            RejectReason::DtypeMismatch { reg: 1, expected: "payload", .. }
        )),
        "{rs:?}"
    );
}

#[test]
fn shift_amount_unbounded() {
    // shift by PktStep's raw value is fine (<= MAX_ROUNDS), but shifting
    // by an unguarded sum of steps is not provably < 64
    assert!(MAX_ROUNDS < 64);
    let mut a = Asm::new();
    let entry = a.label();
    a.bind(entry);
    a.imm(0, 1);
    a.imm(1, 70);
    a.alu(AluOp::Shl, 2, 0, 1);
    a.halt();
    let prog = a.finish("neg-shift", entry, entry);
    let rs = rejects(&prog);
    assert!(has_class(&rs, "shift-range"), "{rs:?}");
}

#[test]
fn emit_destination_provably_off_the_wire() {
    // dst = -1 on every path: disjoint from [0, p), a static fact
    let mut a = Asm::new();
    let entry = a.label();
    a.bind(entry);
    a.imm(0, -1);
    a.imm(1, 0);
    a.ldpkt(2);
    a.emit(0, nfscan::packet::MsgType::Data, 1, 2);
    a.halt();
    let prog = a.finish("neg-wire", entry, entry);
    let rs = rejects(&prog);
    assert!(has_class(&rs, "wire-range"), "{rs:?}");
    let _ = MAX_P; // wire range is defined relative to MAX_P
}

#[test]
fn every_reject_class_displays_distinctly() {
    // the class names are API (negative corpus, lint output, prop test
    // mutation oracle): they must stay unique and stable
    let all = [
        RejectReason::BadRegister { pc: 0, reg: 99 },
        RejectReason::BadTarget { pc: 0, target: 9 },
        RejectReason::BadEntry { which: "on_request", target: 9 },
        RejectReason::MissingHalt { pc: 0 },
        RejectReason::NoTermination { pc: 0 },
        RejectReason::UninitRead { pc: 0, reg: 0 },
        RejectReason::ScratchOob { pc: 0, lo: 64, hi: 64 },
        RejectReason::ShiftRange { pc: 0, lo: 64, hi: 64 },
        RejectReason::DtypeMismatch { pc: 0, reg: 0, expected: "payload" },
        RejectReason::WireRange { pc: 0, lo: -1, hi: -1 },
        RejectReason::BudgetExceeded { entry: "on_packet", bound: 5000 },
    ];
    let mut classes: Vec<&str> = all.iter().map(|r| r.class()).collect();
    classes.sort_unstable();
    classes.dedup();
    assert_eq!(classes.len(), all.len(), "class names must be unique");
    for r in &all {
        assert!(!r.to_string().is_empty());
    }
}
