//! Zero-alloc regression gate for the hot datapath.
//!
//! The tentpole claim of the arena/in-place-combine work is that the
//! steady-state combine/receive path does not allocate.  This binary
//! installs the counting allocator and measures real allocation events
//! around the hot loops.  Everything lives in ONE #[test] fn on purpose:
//! the counters are process-global and libtest runs sibling tests on
//! concurrent threads, which would pollute the deltas.

use nfscan::data::{Op, Payload};
use nfscan::fpga::reassembly::Reassembler;
use nfscan::net::frame::fragment;
use nfscan::runtime::{engine::oracle_prefix, Compute, NativeEngine};
use nfscan::util::alloc as cnt;

#[global_allocator]
static ALLOC: nfscan::util::alloc::CountingAllocator = nfscan::util::alloc::CountingAllocator;

/// Allocation events across `reps` runs of `op`, after `warmup` runs.
fn allocs_of(warmup: usize, reps: usize, mut op: impl FnMut()) -> u64 {
    for _ in 0..warmup {
        op();
    }
    let a0 = cnt::allocation_count();
    for _ in 0..reps {
        op();
    }
    cnt::allocation_count() - a0
}

#[test]
fn hot_datapath_steady_state_allocations() {
    assert!(cnt::counting_installed(), "counting allocator must be the global allocator");
    let e = NativeEngine::new();

    // ---- combine_into on a uniquely-owned accumulator: ZERO allocations
    // per call after warmup, for every dtype (the tentpole claim).
    {
        let mut acc = Payload::from_i32(&(0..1024).collect::<Vec<_>>());
        let b = Payload::from_i32(&(0..1024).map(|v| v % 7 - 3).collect::<Vec<_>>());
        let n = allocs_of(16, 1000, || {
            e.combine_into(&mut acc, &b, Op::Sum).unwrap();
            std::hint::black_box(&acc);
        });
        assert_eq!(n, 0, "i32 steady-state combine_into allocated {n} times in 1000 calls");
    }
    {
        // odd element count: tail-padded arena words must not perturb
        let mut acc = Payload::from_f32(&(0..513).map(|v| v as f32 * 0.25).collect::<Vec<_>>());
        let b = Payload::from_f32(&(0..513).map(|v| v as f32 * 0.5 - 64.0).collect::<Vec<_>>());
        let n = allocs_of(16, 1000, || {
            e.combine_into(&mut acc, &b, Op::Max).unwrap();
            std::hint::black_box(&acc);
        });
        assert_eq!(n, 0, "f32 steady-state combine_into allocated {n} times in 1000 calls");
    }
    {
        let mut acc = Payload::from_f64(&(0..256).map(|v| v as f64).collect::<Vec<_>>());
        let b = Payload::from_f64(&(0..256).map(|v| 1.0 - v as f64).collect::<Vec<_>>());
        let n = allocs_of(16, 1000, || {
            e.combine_into(&mut acc, &b, Op::Min).unwrap();
            std::hint::black_box(&acc);
        });
        assert_eq!(n, 0, "f64 steady-state combine_into allocated {n} times in 1000 calls");
    }
    // the rev direction shares the same machinery
    {
        let mut acc = Payload::from_i32(&(0..500).collect::<Vec<_>>());
        let a = Payload::from_i32(&(0..500).map(|v| -v).collect::<Vec<_>>());
        let n = allocs_of(16, 1000, || {
            e.combine_into_rev(&mut acc, &a, Op::Sum).unwrap();
            std::hint::black_box(&acc);
        });
        assert_eq!(n, 0, "rev steady-state combine_into allocated {n} times in 1000 calls");
    }

    // ---- k-way fold (oracle_prefix): O(1) buffer traffic per whole
    // fold, NOT O(k) allocations.  The cloned head materializes into one
    // pooled buffer (an Rc control block is the only malloc).
    {
        let contribs: Vec<Payload> = (0..64)
            .map(|k| Payload::from_i32(&(0..1024).map(|v| v % 13 - k).collect::<Vec<_>>()))
            .collect();
        let folds = 100;
        let n = allocs_of(4, folds, || {
            let acc = oracle_prefix(&e, &contribs, Op::Sum, true, 63).unwrap();
            std::hint::black_box(&acc);
        });
        let per_fold = n as f64 / folds as f64;
        assert!(
            per_fold <= 2.0,
            "64-way fold averaged {per_fold} allocations (want O(1), got close to O(k)?)"
        );
    }

    // ---- streaming reassembly: the whole-message buffer comes from the
    // pool; per message only constant bookkeeping may allocate.
    {
        let msg = Payload::from_i32(&(0..4096).collect::<Vec<_>>()); // 16 KB, 12 frags
        let frags = fragment(&msg);
        let count = msg.len() as u32;
        let mut r: Reassembler<u32> = Reassembler::new(32);
        let messages = 100;
        let n = allocs_of(4, messages, || {
            let mut whole = None;
            for (idx, total, _off, chunk) in &frags {
                whole = r.add(1, *idx, *total, count, chunk.clone());
            }
            std::hint::black_box(whole.expect("complete"));
        });
        let per_msg = n as f64 / messages as f64;
        assert!(
            per_msg <= 4.0,
            "streaming reassembly averaged {per_msg} allocations per 12-fragment message"
        );
    }

    // ---- a disabled trace is zero-cost: record() rejects before
    // touching the ring, so the instrumented hot path never allocates
    {
        use nfscan::sim::SimTime;
        use nfscan::trace::{SpanData, Trace, TraceKind};
        let mut t = Trace::disabled();
        let mut i = 0u64;
        let n = allocs_of(16, 1000, || {
            i += 1;
            t.record(SimTime::ns(i), 0, TraceKind::NicSend, SpanData::instant(0).txn(i));
            std::hint::black_box(&t);
        });
        assert_eq!(n, 0, "disabled trace recording allocated {n} times in 1000 records");
        assert!(t.is_empty());
    }

    // ---- an enabled trace at capacity recycles the oldest slot:
    // steady-state recording is allocation-free too
    {
        use nfscan::sim::SimTime;
        use nfscan::trace::{SpanData, Trace, TraceKind};
        let mut t = Trace::new(64, true);
        let mut i = 0u64;
        let n = allocs_of(128, 1000, || {
            i += 1;
            t.record(SimTime::ns(i), 0, TraceKind::NicSend, SpanData::instant(0).txn(i));
            std::hint::black_box(&t);
        });
        assert_eq!(n, 0, "at-capacity trace recording allocated {n} times in 1000 records");
        assert_eq!(t.len(), 64);
    }

    // ---- the attribution histogram is fixed-storage by construction
    {
        use nfscan::metrics::LogHistogram;
        let mut h = LogHistogram::new();
        let mut i = 0u64;
        let n = allocs_of(16, 1000, || {
            i += 1;
            h.record(i * 37);
            std::hint::black_box(&h);
        });
        assert_eq!(n, 0, "histogram recording allocated {n} times in 1000 records");
    }

    // ---- the arena pool really is recycling (hits grew during the runs)
    let (hits, _misses) = nfscan::data::arena::pool_stats();
    assert!(hits > 0, "arena pool never served a recycled buffer");
}
