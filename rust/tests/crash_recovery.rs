//! Fail-stop regression corpus: crash schedules replayed from TOML
//! configs, heartbeat detection, reroute-and-degrade recovery, and the
//! no-crash invariants that keep a crash-free fabric byte-identical to
//! the pre-failure simulator.
//!
//! The scenarios here are the locked-in contract for the failure model:
//! - a scheduled rank death is detected, the group shrinks, and the
//!   survivors complete with survivor-oracle values — never a hang;
//! - a redundant-path switch death reroutes and the full group still
//!   finishes; a trunk death that partitions survivors is a NAMED
//!   error;
//! - corrupted frames fail the CRC, count as drops, and ride the
//!   existing retransmit path; reordered frames still verify;
//! - the `crash` sweep axis is deterministic across worker counts, and
//!   a `crash = [""]` grid is byte-identical to one that never mentions
//!   crashes at all.

use std::path::PathBuf;
use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExpConfig};
use nfscan::runtime::make_engine;
use nfscan::sweep::{run_grid, GridSpec};

fn native() -> Rc<dyn nfscan::runtime::Compute> {
    make_engine(EngineKind::Native, "artifacts")
}

/// Replay one TOML experiment (the failure schedules live in the config
/// text, exactly as a user would commit them) and return its metrics.
fn replay(toml: &str) -> nfscan::metrics::RunMetrics {
    let cfg = ExpConfig::from_toml(toml).expect("scenario config parses");
    let mut cluster = Cluster::new(cfg, native());
    cluster.run().expect("scenario terminates cleanly")
}

#[test]
fn scheduled_rank_death_shrinks_the_group_and_completes() {
    // Rank 1 fail-stops at the top of its 3rd epoch on a hypercube.
    // Its silence must be detected (ack give-up or probe), the fabric
    // rerouted, and every stuck survivor epoch completed over the
    // shrunk group — with verify on, the in-run verifier accepts the
    // survivor-oracle values for degraded epochs.
    let m = replay(
        r#"
        [run]
        p = 4
        algo = "rd"
        path = "fpga"
        msg_bytes = 64
        iters = 8
        warmup = 0
        verify = true
        crash = "rank:1@epoch:2"

        [cost]
        max_retries = 8
        "#,
    );
    assert_eq!(m.crashes, 1, "exactly the scheduled death");
    assert_eq!(m.false_suspicions, 0, "no healthy rank was evicted");
    assert!(m.detection_ns > 0, "death-to-verdict latency must be attributed");
    assert!(m.reroutes >= 1, "the corpse must leave the route table");
    assert!(m.degraded_completions >= 1, "stuck survivor epochs complete shrunk");
}

#[test]
fn redundant_switch_death_reroutes_and_the_full_group_finishes() {
    // Kill one aggregation switch of a p = 8 fat-tree mid-run: BFS
    // recomputation routes around it through the pod's sibling, every
    // rank survives, and the run completes full-group (no degradation).
    // Frames in flight through the corpse are dropped and re-covered by
    // the retransmit layer.
    let m = replay(
        r#"
        [run]
        p = 8
        algo = "rd"
        path = "fpga"
        topology = "fattree"
        msg_bytes = 256
        iters = 8
        warmup = 0
        verify = true
        crash = "switch:3@ns:300000"

        [cost]
        max_retries = 8
        "#,
    );
    assert_eq!(m.crashes, 1, "exactly the scheduled switch death");
    assert!(m.reroutes >= 1, "the fabric must be rerouted around the corpse");
    assert_eq!(m.degraded_completions, 0, "no rank died — the full group finishes");
    assert_eq!(m.false_suspicions, 0, "rerouting must not smell like a rank death");
}

#[test]
fn trunk_switch_death_is_a_named_partition_error() {
    // A star fabric has no redundant paths: killing a leaf switch
    // strands its hosts, no protocol can terminate across the cut, and
    // the run must FAIL with an error naming the partition — not hang
    // until a watchdog or the test harness gives up.
    let cfg = ExpConfig::from_toml(
        r#"
        [run]
        p = 8
        algo = "rd"
        path = "fpga"
        topology = "star:4"
        msg_bytes = 64
        iters = 4
        warmup = 0
        verify = false
        crash = "switch:0@ns:200000"
        "#,
    )
    .expect("scenario config parses");
    let mut cluster = Cluster::new(cfg, native());
    let err = format!("{:#}", cluster.run().expect_err("a partition must be an error"));
    assert!(err.contains("partition"), "error must name the partition: {err}");
    assert!(err.contains("star"), "error must name the topology: {err}");
}

#[test]
fn dead_bcast_root_is_a_structured_degraded_failure() {
    // Shrinking cannot save a broadcast whose root died before epoch 1:
    // no survivor holds the data.  The run must surface the structured
    // (coll, epoch, dead ranks) failure — named, attributable, never a
    // hang against the silent peer.
    let cfg = ExpConfig::from_toml(
        r#"
        [run]
        p = 4
        algo = "rd"
        path = "sw"
        coll = "bcast"
        msg_bytes = 64
        iters = 4
        warmup = 0
        verify = false
        crash = "rank:0@epoch:1"
        "#,
    )
    .expect("scenario config parses");
    let mut cluster = Cluster::new(cfg, native());
    let err = format!("{:#}", cluster.run().expect_err("a dead root must be an error"));
    assert!(err.contains("degraded failure"), "{err}");
    assert!(err.contains("bcast"), "error must name the collective: {err}");
    assert!(err.contains("dead ranks"), "error must name the dead set: {err}");
}

#[test]
fn corrupted_frame_fails_crc_and_rides_the_retransmit_path() {
    // Mangle the first frame on the 0 -> 1 wire: the receiver's CRC
    // check must reject it pre-ack, the sender's timer re-covers it,
    // and the scan still verifies against the oracle.
    let m = replay(
        r#"
        [run]
        p = 2
        algo = "seq"
        path = "fpga"
        msg_bytes = 64
        iters = 2
        warmup = 0
        verify = true
        corrupt = "0->1:1"
        "#,
    );
    assert!(m.retransmits >= 1, "a CRC-rejected frame must be resent");
    assert!(m.timeouts_fired >= 1, "the resend is timer-driven");
    assert!(m.recovery_ns > 0, "recovery latency must be attributed");
}

#[test]
fn reordered_frames_still_verify() {
    // Hold the first 0 -> 1 frame back so a later one overtakes it:
    // reassembly and the dedup layer must absorb the inversion and the
    // results must still be oracle-exact (verify is on).
    let m = replay(
        r#"
        [run]
        p = 4
        algo = "rd"
        path = "fpga"
        msg_bytes = 4096
        iters = 4
        warmup = 0
        verify = true
        reorder = "0->1:1"
        "#,
    );
    assert!(m.total_frames() > 0);
}

const CHAOS_GRID: &str = r#"
    [grid]
    name = "chaos"
    sizes = [64]
    p = [8]
    series = ["NF_rd"]
    loss = [0.0, 0.02]
    crash = ["", "rank:3@epoch:4"]

    [run]
    iters = 8
    warmup = 2
    seed = 9
    verify = true

    [cost]
    max_retries = 8
"#;

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nfscan_crash_{tag}_{}", std::process::id()))
}

#[test]
fn chaos_grid_artifacts_identical_for_jobs_1_and_4() {
    // Failure recovery is event-driven simulation, not wall clock: a
    // crash-axis grid must produce byte-identical artifacts for any
    // worker count, its crashed cells must record the death and shrunk
    // completions, and its baseline cells must record neither.
    let spec = GridSpec::from_toml(CHAOS_GRID).unwrap();
    let d1 = scratch("j1");
    let d4 = scratch("j4");
    let files1 = run_grid(&spec, 1, "artifacts").unwrap().write_artifacts(&d1).unwrap();
    let files4 = run_grid(&spec, 4, "artifacts").unwrap().write_artifacts(&d4).unwrap();
    assert!(!files1.is_empty());
    assert!(
        files1.iter().any(|f| f.file_name().unwrap().to_string_lossy() == "fig_recovery.json"),
        "a crash/loss grid must emit the recovery-cost figure"
    );
    for (a, b) in files1.iter().zip(files4.iter()) {
        assert_eq!(
            std::fs::read(a).unwrap(),
            std::fs::read(b).unwrap(),
            "{} differs between --jobs 1 and --jobs 4",
            a.file_name().unwrap().to_string_lossy()
        );
    }

    let report = run_grid(&spec, 2, "artifacts").unwrap();
    let doc = report.to_json();
    let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
    assert_eq!(jobs.len(), 4, "2 loss x 2 crash cells");
    let crashed: Vec<_> = jobs.iter().filter(|j| j.get("crash").is_some()).collect();
    assert_eq!(crashed.len(), 2, "the crash schedule tags exactly its cells");
    for j in &crashed {
        assert_eq!(j.get("crashes").unwrap().as_u64(), Some(1));
        assert!(j.get("degraded_completions").unwrap().as_u64().unwrap() >= 1);
    }
    for j in jobs.iter().filter(|j| j.get("crash").is_none()) {
        assert!(j.get("crashes").is_none(), "crash-free cells stay schema-clean");
        assert!(j.get("degraded_completions").is_none());
    }

    std::fs::remove_dir_all(&d1).ok();
    std::fs::remove_dir_all(&d4).ok();
}

#[test]
fn empty_crash_axis_is_byte_invisible() {
    // A grid that says `crash = [""]` and one that never mentions
    // crashes must emit byte-identical reports: job indices, derived
    // seeds, schedules, metrics — everything.  Same no-regression
    // anchor as the loss axis, extended to the failure model.
    let with_key = CHAOS_GRID
        .replace("crash = [\"\", \"rank:3@epoch:4\"]", "crash = [\"\"]")
        .replace("loss = [0.0, 0.02]", "loss = [0.0]");
    let without_key = with_key.replace("crash = [\"\"]\n", "");
    let a = run_grid(&GridSpec::from_toml(&with_key).unwrap(), 2, "artifacts").unwrap();
    let b = run_grid(&GridSpec::from_toml(&without_key).unwrap(), 2, "artifacts").unwrap();
    assert_eq!(a.to_json().pretty(), b.to_json().pretty());
}

#[test]
fn quiet_corrupt_and_reorder_knobs_are_byte_invisible() {
    // Explicit empty corrupt/reorder schedules in [run] must leave the
    // report byte-identical to a config that never mentions them.
    let quiet = CHAOS_GRID
        .replace("crash = [\"\", \"rank:3@epoch:4\"]", "")
        .replace("loss = [0.0, 0.02]", "loss = [0.0]");
    let explicit = quiet.replace("verify = true", "verify = true\n    corrupt = \"\"\n    reorder = \"\"");
    let a = run_grid(&GridSpec::from_toml(&quiet).unwrap(), 2, "artifacts").unwrap();
    let b = run_grid(&GridSpec::from_toml(&explicit).unwrap(), 2, "artifacts").unwrap();
    assert_eq!(a.to_json().pretty(), b.to_json().pretty());
}
