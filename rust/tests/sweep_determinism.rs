//! Regression: sweep artifacts are byte-identical regardless of worker
//! count, and the built-in figs grid emits all four figure artifacts.

use std::path::PathBuf;

use nfscan::metrics::json::Json;
use nfscan::sweep::{run_grid, GridSpec};

const GRID: &str = r#"
    [grid]
    name = "det"
    sizes = [4, 256]
    p = [4, 8]
    series = ["sw_seq", "sw_rd", "NF_rd"]

    [run]
    iters = 15
    warmup = 3
    seed = 99
"#;

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nfscan_sweep_{tag}_{}", std::process::id()))
}

#[test]
fn artifact_bytes_identical_for_jobs_1_and_4() {
    let spec = GridSpec::from_toml(GRID).unwrap();
    let d1 = scratch("j1");
    let d4 = scratch("j4");

    let files1 = run_grid(&spec, 1, "artifacts").unwrap().write_artifacts(&d1).unwrap();
    let files4 = run_grid(&spec, 4, "artifacts").unwrap().write_artifacts(&d4).unwrap();

    let names = |files: &[PathBuf]| -> Vec<String> {
        files.iter().map(|f| f.file_name().unwrap().to_string_lossy().into_owned()).collect()
    };
    assert_eq!(names(&files1), names(&files4));
    assert!(!files1.is_empty());
    for (a, b) in files1.iter().zip(files4.iter()) {
        let bytes_a = std::fs::read(a).unwrap();
        let bytes_b = std::fs::read(b).unwrap();
        assert_eq!(
            bytes_a,
            bytes_b,
            "{} differs between --jobs 1 and --jobs 4",
            a.file_name().unwrap().to_string_lossy()
        );
    }

    std::fs::remove_dir_all(&d1).ok();
    std::fs::remove_dir_all(&d4).ok();
}

#[test]
fn figs_grid_emits_all_four_figures() {
    // the paper grid, scaled down so the test stays fast; the artifact
    // set and schema are exactly what `nfscan sweep --grid figs` writes
    let mut spec = GridSpec::figs(15);
    spec.base.warmup = 3;
    spec.sizes = vec![4, 1024];

    let dir = scratch("figs");
    let report = run_grid(&spec, 4, "artifacts").unwrap();
    let files = report.write_artifacts(&dir).unwrap();
    let names: Vec<String> = files
        .iter()
        .map(|f| f.file_name().unwrap().to_string_lossy().into_owned())
        .collect();
    assert_eq!(names, vec!["figs.json", "fig4.json", "fig5.json", "fig6.json", "fig7.json"]);

    let fig4 = Json::parse(&std::fs::read_to_string(dir.join("fig4.json")).unwrap()).unwrap();
    let series = fig4.get("series").unwrap().as_arr().unwrap();
    assert_eq!(series.len(), 5, "fig4 carries all five measured series");
    let col = |name: &str| -> Vec<f64> {
        series
            .iter()
            .find(|c| c.get("name").unwrap().as_str() == Some(name))
            .unwrap()
            .get("values_us")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect()
    };
    // paper shape survives the sweep pipeline: offload beats software rd
    for (nf, sw) in col("NF_rd").iter().zip(col("sw_rd").iter()) {
        assert!(nf < sw, "NF_rd {nf} must beat sw_rd {sw} (paper Fig. 4)");
    }

    let fig6 = Json::parse(&std::fs::read_to_string(dir.join("fig6.json")).unwrap()).unwrap();
    assert_eq!(
        fig6.get("series").unwrap().as_arr().unwrap().len(),
        3,
        "fig6 keeps only the NF series"
    );
    assert_eq!(fig6.get("metric").unwrap().as_str(), Some("nic_avg_us"));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn default_artifacts_carry_no_observability_fields() {
    // the observability schema additions are conditional: a grid that
    // never asked for attribution or a late_rank axis must emit
    // artifacts with none of the new keys (pre-PR byte compatibility)
    let spec = GridSpec::from_toml(GRID).unwrap();
    let text = run_grid(&spec, 2, "artifacts").unwrap().to_json().pretty();
    for key in ["attribution", "wire_ns", "late_rank", "host_hist"] {
        assert!(!text.contains(key), "default artifacts must not mention {key:?}");
    }
}

#[test]
fn attribution_artifacts_identical_for_jobs_1_and_4() {
    // attribution pools per-rank accumulators across the run; the
    // breakdown must still be a pure function of the cell, not of
    // worker scheduling
    let spec = GridSpec::from_toml(&GRID.replace("seed = 99", "seed = 99\nattribution = true"))
        .unwrap();
    let a = run_grid(&spec, 1, "artifacts").unwrap().to_json().pretty();
    let b = run_grid(&spec, 4, "artifacts").unwrap().to_json().pretty();
    assert_eq!(a, b, "attribution-on artifacts differ between --jobs 1 and --jobs 4");
    assert!(a.contains("wire_ns"), "every cell carries the breakdown");

    // and each job's components sum exactly to its latency_ns
    let doc = Json::parse(&a).unwrap();
    let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
    assert!(!jobs.is_empty());
    for j in jobs {
        let attr = j.get("attribution").unwrap();
        let f = |k: &str| attr.get(k).unwrap().as_u64().unwrap();
        let sum = f("wire_ns")
            + f("switch_queue_ns")
            + f("hpu_queue_ns")
            + f("handler_exec_ns")
            + f("compute_ns")
            + f("recovery_ns")
            + f("host_ns");
        assert_eq!(sum, f("latency_ns"), "job {:?}", j.get("index"));
    }
}

#[test]
fn reseeded_master_changes_artifacts() {
    // the derived-seed scheme must actually feed the simulations: a
    // different master seed must produce different latency samples
    let spec_a = GridSpec::from_toml(GRID).unwrap();
    let spec_b = GridSpec::from_toml(&GRID.replace("seed = 99", "seed = 100")).unwrap();
    let a = run_grid(&spec_a, 2, "artifacts").unwrap();
    let b = run_grid(&spec_b, 2, "artifacts").unwrap();
    assert_ne!(a.to_json().pretty(), b.to_json().pretty());
}
