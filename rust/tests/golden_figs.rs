//! Golden-artifact regression: a committed reference `fig4.json`
//! produced by the seed cost model, byte-compared against a fresh
//! `sweep --grid figs --jobs 2` run on every `cargo test`.
//!
//! The jobs-count determinism test (`sweep_determinism.rs`) only proves a
//! sweep agrees with *itself*; this one pins the absolute numbers, so a
//! silent cost-model change (a default constant nudged, a charge moved,
//! a fold reordered) fails loudly instead of drifting the figures.
//!
//! Blessing: if `tests/golden/fig4.json` does not exist yet, the test
//! writes the freshly computed artifact there and passes with a notice —
//! commit the generated file to arm the regression.  To intentionally
//! re-bless after a deliberate cost-model change, delete the file and
//! re-run `cargo test`.

use std::path::PathBuf;

use nfscan::sweep::{run_grid, GridSpec};

/// The golden contract: the built-in figs grid (five paper series x the
/// OSU size ladder, p = 8) at a fixed iteration count, merged over two
/// workers.  Everything here is deterministic from the spec.
const GOLDEN_ITERS: usize = 20;
const GOLDEN_JOBS: usize = 2;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/fig4.json")
}

#[test]
fn fig4_matches_committed_golden() {
    let spec = GridSpec::figs(GOLDEN_ITERS);
    let report = run_grid(&spec, GOLDEN_JOBS, "artifacts").expect("figs grid runs");
    let fresh = report.figure_json("fig4").expect("fig4 renders").pretty();

    let path = golden_path();
    if !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("mkdir tests/golden");
        std::fs::write(&path, &fresh).expect("write golden");
        eprintln!(
            "golden fig4.json was missing — blessed a fresh one at {}; \
             commit it to arm the cost-model regression gate",
            path.display()
        );
        return;
    }
    let committed = std::fs::read_to_string(&path).expect("read golden");
    assert_eq!(
        fresh,
        committed,
        "fig4 drifted from the committed golden ({}).  If the cost-model \
         change is intentional, delete the file and re-run cargo test to \
         re-bless; otherwise this is a silent regression.",
        path.display()
    );
}
