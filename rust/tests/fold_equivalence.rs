//! Fold-equivalence property: the in-place combine path must be
//! BIT-IDENTICAL to the allocating path and to the oracle fold, across
//! dtype x op x payload length x window alignment.  This is the proof
//! obligation behind rewiring every state machine onto `combine_into` —
//! figure artifacts byte-compare in CI, and this test pins the engine
//! layer underneath them.

use nfscan::data::{Dtype, Op, Payload};
use nfscan::runtime::{engine::oracle_prefix, Compute, NativeEngine};
use nfscan::sim::SplitMix64;

fn random_payload(rng: &mut SplitMix64, dtype: Dtype, n: usize) -> Payload {
    match dtype {
        Dtype::I32 => {
            Payload::from_i32(&(0..n).map(|_| rng.range_i64(-50, 50) as i32).collect::<Vec<_>>())
        }
        Dtype::F32 => Payload::from_f32(
            &(0..n).map(|_| (rng.next_f64() * 4.0 - 2.0) as f32).collect::<Vec<_>>(),
        ),
        Dtype::F64 => {
            Payload::from_f64(&(0..n).map(|_| rng.next_f64() * 4.0 - 2.0).collect::<Vec<_>>())
        }
    }
}

/// Pairwise fold with the allocating `combine` (the pre-refactor shape).
fn pairwise(e: &dyn Compute, xs: &[Payload], op: Op) -> Payload {
    let mut acc = xs[0].clone();
    for c in &xs[1..] {
        acc = e.combine(&acc, c, op).unwrap();
    }
    acc
}

/// In-place fold with `combine_into`.
fn in_place(e: &dyn Compute, xs: &[Payload], op: Op) -> Payload {
    let mut acc = xs[0].clone();
    for c in &xs[1..] {
        e.combine_into(&mut acc, c, op).unwrap();
    }
    acc
}

#[test]
fn in_place_fold_equals_pairwise_equals_oracle() {
    let e = NativeEngine::new();
    let mut rng = SplitMix64::new(0xF01D);
    for dtype in Dtype::ALL {
        for op in Op::ALL {
            if !op.valid_for(dtype) {
                continue;
            }
            for n in [1usize, 3, 8, 61, 500] {
                let xs: Vec<Payload> =
                    (0..5).map(|_| random_payload(&mut rng, dtype, n)).collect();
                let a = pairwise(&e, &xs, op);
                let b = in_place(&e, &xs, op);
                let c = oracle_prefix(&e, &xs, op, true, 4).unwrap();
                assert_eq!(
                    a.bytes(),
                    b.bytes(),
                    "{dtype:?} {op:?} n={n}: in-place fold != pairwise combine"
                );
                assert_eq!(
                    a.bytes(),
                    c.bytes(),
                    "{dtype:?} {op:?} n={n}: oracle fold != pairwise combine"
                );
            }
        }
    }
}

#[test]
fn rev_direction_matches_swapped_combine() {
    let e = NativeEngine::new();
    let mut rng = SplitMix64::new(0xBEEF);
    for dtype in Dtype::ALL {
        for op in Op::ALL {
            if !op.valid_for(dtype) {
                continue;
            }
            for n in [1usize, 17, 200] {
                let a = random_payload(&mut rng, dtype, n);
                let b = random_payload(&mut rng, dtype, n);
                let want = e.combine(&a, &b, op).unwrap();
                let mut acc = b.clone();
                e.combine_into_rev(&mut acc, &a, op).unwrap();
                assert_eq!(acc.bytes(), want.bytes(), "{dtype:?} {op:?} n={n} rev");
            }
        }
    }
}

#[test]
fn folds_over_unaligned_wire_windows() {
    // windows at odd element offsets: 4-byte dtypes land on non-8B
    // boundaries (the wire-slice case).  Both operand positions and both
    // directions must match the allocating path bit-for-bit.
    let e = NativeEngine::new();
    let mut rng = SplitMix64::new(0x51DE);
    for dtype in [Dtype::I32, Dtype::F32, Dtype::F64] {
        for op in [Op::Sum, Op::Max, Op::Prod] {
            let whole_a = random_payload(&mut rng, dtype, 130);
            let whole_b = random_payload(&mut rng, dtype, 130);
            for (start, n) in [(1usize, 64usize), (3, 9), (7, 123)] {
                let wa = whole_a.slice(start, n);
                let wb = whole_b.slice(start, n);
                let want = e.combine(&wa, &wb, op).unwrap();
                // window as accumulator (materializes on first fold)
                let mut acc = wa.clone();
                e.combine_into(&mut acc, &wb, op).unwrap();
                assert_eq!(acc.bytes(), want.bytes(), "{dtype:?} {op:?} window acc");
                // window as the read operand
                let mut acc = wa.clone();
                let b_owned = Payload::from_bytes(dtype, wb.bytes().to_vec());
                e.combine_into(&mut acc, &b_owned, op).unwrap();
                assert_eq!(acc.bytes(), want.bytes(), "{dtype:?} {op:?} owned b");
                let mut acc = wb.clone();
                e.combine_into_rev(&mut acc, &wa, op).unwrap();
                assert_eq!(acc.bytes(), want.bytes(), "{dtype:?} {op:?} window rev");
                // CoW forked: the shared whole-message backing is intact
                assert_eq!(whole_a.slice(start, n).bytes(), wa.bytes());
                assert_eq!(whole_b.slice(start, n).bytes(), wb.bytes());
            }
        }
    }
}

#[test]
fn scan_and_derive_unchanged_by_refactor() {
    // spot-check the non-fold engine entry points still agree with the
    // oracle shapes (they kept the allocating path)
    let e = NativeEngine::new();
    let x = Payload::from_i32(&[1, 2, 3, 4]);
    assert_eq!(e.scan(&x, Op::Sum, true).unwrap().to_i32(), vec![1, 3, 6, 10]);
    let own = Payload::from_i32(&[5, -7]);
    let peer = Payload::from_i32(&[3, 11]);
    let cum = e.combine(&peer, &own, Op::Sum).unwrap();
    assert_eq!(e.derive(&cum, &own).unwrap().to_i32(), peer.to_i32());
}
