//! Hostile-network regression corpus: drop schedules replayed from TOML
//! configs, NIC-level timeout/retransmit recovery on every path, and the
//! no-fault invariants that keep a lossless fabric byte-identical to the
//! pre-fault simulator.
//!
//! The scenarios here are the locked-in contract for the fault model:
//! - scheduled drops (first fragment, acks, exhaustion) recover — or
//!   fail loudly with the `(coll, rank, epoch)` flow identity, never
//!   hang;
//! - recovery composes with the straggler model and random loss while
//!   results still verify against the oracle;
//! - the `loss` sweep axis is deterministic across worker counts, and a
//!   `loss = [0.0]` grid is byte-identical to one that never mentions
//!   loss at all;
//! - the committed golden `fig4.json` stays untouched: the figs grid is
//!   pinned to a lossless fabric.

use std::path::PathBuf;
use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExpConfig};
use nfscan::runtime::make_engine;
use nfscan::sweep::{run_grid, GridSpec};

fn native() -> Rc<dyn nfscan::runtime::Compute> {
    make_engine(EngineKind::Native, "artifacts")
}

/// Replay one TOML experiment (the drop schedules live in the config
/// text, exactly as a user would commit them) and return its metrics.
fn replay(toml: &str) -> nfscan::metrics::RunMetrics {
    let cfg = ExpConfig::from_toml(toml).expect("scenario config parses");
    let mut cluster = Cluster::new(cfg, native());
    cluster.run().expect("scenario recovers")
}

#[test]
fn dropped_first_fragment_is_retransmitted_and_verifies() {
    // 4096 B payload -> 3 MTU fragments; the schedule kills the very
    // first frame rank 0 puts on the wire (fragment 1 of its data).
    // Recovery must resend it, reassembly must complete, and the scan
    // must still verify against the oracle.
    let m = replay(
        r#"
        [run]
        p = 2
        algo = "seq"
        path = "fpga"
        msg_bytes = 4096
        iters = 2
        warmup = 0
        verify = true
        drop = "0->1:1"
        "#,
    );
    assert!(m.retransmits >= 1, "the dropped fragment must be resent");
    assert!(m.timeouts_fired >= 1, "the resend is timer-driven");
    assert!(m.recovery_ns > 0, "recovery latency must be attributed");
}

#[test]
fn dropped_ack_is_covered_by_retransmit_and_dedup() {
    // Kill the first frame on the REVERSE edge (1 -> 0): whichever ack
    // that is — the transport-level RelAck or the collective-level
    // flow-control ACK — the sender's timer re-covers it, the receiver
    // deduplicates the duplicate data, and values stay correct.  The
    // TOML-array drop form is part of the contract.
    let m = replay(
        r#"
        [run]
        p = 2
        algo = "seq"
        path = "fpga"
        msg_bytes = 64
        iters = 2
        warmup = 0
        verify = true
        drop = ["1->0:1"]
        "#,
    );
    assert!(m.retransmits >= 1, "a lost ack must trigger a resend");
    assert!(m.timeouts_fired >= m.retransmits);
}

#[test]
fn retry_exhaustion_is_a_named_error_not_a_hang() {
    // Enough consecutive drops on 0 -> 1 to outlast max_retries = 2:
    // the run must FAIL (no silent wrong answer, no hang) and the error
    // must name the flow — collective, rank, epoch — so the victim is
    // identifiable from the message alone.
    let drops: Vec<String> = (1..=12).map(|n| format!("0->1:{n}")).collect();
    let toml = format!(
        r#"
        [run]
        p = 2
        algo = "seq"
        path = "fpga"
        msg_bytes = 64
        iters = 1
        warmup = 0
        verify = false
        drop = "{}"

        [cost]
        max_retries = 2
        "#,
        drops.join(", ")
    );
    let cfg = ExpConfig::from_toml(&toml).expect("scenario config parses");
    let mut cluster = Cluster::new(cfg, native());
    let err = format!("{:#}", cluster.run().expect_err("exhaustion must error"));
    assert!(err.contains("recovery failed"), "{err}");
    assert!(err.contains("rank"), "error must name the rank: {err}");
    assert!(err.contains("epoch"), "error must name the epoch: {err}");
}

#[test]
fn straggler_plus_random_loss_still_verifies() {
    // The fault layer composes with the late-rank straggler model: a
    // delayed rank under 5% random loss must still recover every frame
    // and produce oracle-exact results.
    let m = replay(
        r#"
        [run]
        p = 4
        algo = "rd"
        path = "fpga"
        msg_bytes = 256
        iters = 20
        warmup = 2
        verify = true
        seed = 11
        loss = 0.05
        late_rank = 1
        late_delay_ns = 200000

        [cost]
        max_retries = 8
        "#,
    );
    assert!(m.retransmits > 0, "5% loss over hundreds of frames must drop something");
    assert!(m.timeouts_fired >= m.retransmits);
    assert!(m.recovery_ns > 0);
}

const HOSTILE_GRID: &str = r#"
    [grid]
    name = "hostile"
    sizes = [64, 1024]
    p = [4]
    series = ["NF_rd", "handler:scan"]
    loss = [0.0, 0.03]

    [run]
    iters = 8
    warmup = 2
    seed = 7

    [cost]
    max_retries = 8
"#;

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nfscan_fault_{tag}_{}", std::process::id()))
}

#[test]
fn loss_grid_artifacts_identical_for_jobs_1_and_4() {
    // Recovery is event-driven simulation, not wall clock: a lossy grid
    // must produce byte-identical artifacts for any worker count, and
    // its lossy cells must actually record recovery work.
    let spec = GridSpec::from_toml(HOSTILE_GRID).unwrap();
    let d1 = scratch("j1");
    let d4 = scratch("j4");
    let files1 = run_grid(&spec, 1, "artifacts").unwrap().write_artifacts(&d1).unwrap();
    let files4 = run_grid(&spec, 4, "artifacts").unwrap().write_artifacts(&d4).unwrap();
    assert!(!files1.is_empty());
    for (a, b) in files1.iter().zip(files4.iter()) {
        assert_eq!(
            std::fs::read(a).unwrap(),
            std::fs::read(b).unwrap(),
            "{} differs between --jobs 1 and --jobs 4",
            a.file_name().unwrap().to_string_lossy()
        );
    }

    let report = run_grid(&spec, 2, "artifacts").unwrap();
    let doc = report.to_json();
    let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
    let lossy_retx: u64 = jobs
        .iter()
        .filter(|j| j.get("loss").unwrap().as_f64() == Some(0.03))
        .map(|j| j.get("retransmits").unwrap().as_u64().unwrap())
        .sum();
    let clean_retx: u64 = jobs
        .iter()
        .filter(|j| j.get("loss").unwrap().as_f64() == Some(0.0))
        .map(|j| j.get("retransmits").unwrap().as_u64().unwrap())
        .sum();
    assert!(lossy_retx > 0, "3% cells must record retransmits");
    assert_eq!(clean_retx, 0, "lossless cells must record none");

    std::fs::remove_dir_all(&d1).ok();
    std::fs::remove_dir_all(&d4).ok();
}

#[test]
fn loss_zero_axis_is_byte_invisible() {
    // A grid that says `loss = [0.0]` and one that never mentions loss
    // must emit byte-identical artifacts: job indices, derived seeds,
    // schedules, metrics — everything.  This is the no-regression
    // anchor for every pre-fault artifact consumer.
    let with_key = HOSTILE_GRID.replace("loss = [0.0, 0.03]", "loss = [0.0]");
    let without_key = HOSTILE_GRID.replace("loss = [0.0, 0.03]\n", "");
    let a = run_grid(&GridSpec::from_toml(&with_key).unwrap(), 2, "artifacts").unwrap();
    let b = run_grid(&GridSpec::from_toml(&without_key).unwrap(), 2, "artifacts").unwrap();
    assert_eq!(a.to_json().pretty(), b.to_json().pretty());
}

#[test]
fn figs_grid_stays_lossless_and_golden_fig4_is_untouched() {
    // The paper-figure grid is pinned to loss = [0.0], so the committed
    // golden fig4.json must be reproduced byte-for-byte by the
    // post-fault-model code.  Mirrors golden_figs.rs' parameters
    // (iters = 20, jobs = 2) on purpose: same contract, asserted from
    // the fault suite so a fault-layer change that perturbs the
    // lossless schedule fails HERE with the hostile-network context.
    let spec = GridSpec::figs(20);
    assert_eq!(spec.losses, vec![0.0], "figs must run on a lossless fabric");

    let golden = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/fig4.json");
    if !golden.exists() {
        // golden_figs.rs blesses on first run; nothing to compare yet
        return;
    }
    let report = run_grid(&spec, 2, "artifacts").expect("figs grid runs");
    let fresh = report.figure_json("fig4").expect("fig4 renders").pretty();
    let committed = std::fs::read_to_string(&golden).unwrap();
    assert_eq!(
        fresh, committed,
        "fault layer perturbed the lossless schedule: fig4 drifted from the golden"
    );
    let doc = report.to_json();
    for j in doc.get("jobs").unwrap().as_arr().unwrap() {
        assert_eq!(j.get("retransmits").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("timeouts_fired").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("recovery_ns").unwrap().as_u64(), Some(0));
    }
}
