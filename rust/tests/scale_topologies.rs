//! Scaling past the paper's testbed: end-to-end runs on the hierarchical
//! multi-switch topologies at p = 64..256, with every rank's result still
//! verified against the oracle (`cfg.verify`).
//!
//! The paper evaluates on "a small configuration" and names scaling as
//! open work (SSVI); NIC-based collective trees only get interesting once
//! they span many switches.  These tests pin down that the simulator's
//! scaled fabrics stay correct and that host-observed latency grows
//! O(log p), not O(p), along the fat-tree axis.

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::metrics::RunMetrics;
use nfscan::packet::AlgoType;
use nfscan::runtime::make_engine;

fn run(p: usize, topology: &str, algo: AlgoType, iters: usize) -> RunMetrics {
    let mut cfg = ExpConfig::default();
    cfg.p = p;
    cfg.algo = algo;
    cfg.path = ExecPath::Fpga;
    cfg.topology = topology.into();
    cfg.msg_bytes = 4;
    cfg.iters = iters;
    cfg.warmup = 1;
    cfg.verify = true;
    cfg.cost.start_jitter_ns = 0;
    let compute = make_engine(EngineKind::Native, "artifacts");
    let mut cluster = Cluster::new(cfg, compute);
    cluster.run().unwrap_or_else(|e| panic!("{algo:?} p={p} on {topology}: {e}"))
}

#[test]
fn fattree_p64_verifies_all_tree_algorithms() {
    for algo in [AlgoType::RecursiveDoubling, AlgoType::BinomialTree] {
        let m = run(64, "fattree", algo, 3);
        assert_eq!(m.host_overall().count(), 64 * 3, "{algo:?}");
        assert!(m.switch_frames_forwarded > 0, "{algo:?} must cross the fabric");
        assert_eq!(
            m.frames_forwarded.iter().sum::<u64>(),
            0,
            "{algo:?}: hosts are leaves; only switches forward"
        );
    }
}

#[test]
fn star_p64_verifies_and_trunk_serializes() {
    // 8 leaves of 8 hosts: every cross-leaf flow squeezes through one
    // uplink, so the trunk must carry (and serialize) real traffic
    let m = run(64, "star:8", AlgoType::RecursiveDoubling, 3);
    assert_eq!(m.host_overall().count(), 64 * 3);
    assert!(m.switch_frames_tx > m.total_frames() / 2, "trunks carry most frames");
}

#[test]
fn sequential_scales_past_the_card_on_a_chain() {
    // the direct chain needs no switches at any p — the paper's wiring,
    // just longer; 100 ranks exercises deep pipelining
    let m = run(100, "chain", AlgoType::Sequential, 3);
    assert_eq!(m.host_overall().count(), 100 * 3);
    assert_eq!(m.switch_frames_tx, 0);
}

#[test]
fn fattree_latency_grows_logarithmically() {
    // p 8 -> 64 is log-factor 2 (3 -> 6 recursive-doubling steps); the
    // fat-tree adds a bounded number of switch hops per step, so the
    // host-observed average must grow clearly sublinearly: well under
    // the 8x of an O(p) algorithm, around the 2x of O(log p).
    let lat8 = run(8, "fattree", AlgoType::RecursiveDoubling, 6).host_overall().avg_ns();
    let lat64 = run(64, "fattree", AlgoType::RecursiveDoubling, 6).host_overall().avg_ns();
    assert!(lat64 > lat8, "more ranks cannot be free: {lat64} vs {lat8}");
    assert!(
        lat64 < 3.0 * lat8,
        "p=64 fat-tree latency {lat64} must stay near 2x the p=8 latency {lat8} (O(log p)), \
         nowhere near the 8x of O(p)"
    );
}

/// The acceptance-criteria smoke at p=256 (k=12 fat-tree, 436 graph
/// nodes).  Heavy for the debug-mode tier-1 run, so it is `#[ignore]`d
/// there; CI runs it in release via `cargo test --release -- --ignored`.
#[test]
#[ignore = "p=256 release-mode smoke; run with --release -- --ignored"]
fn fattree_p256_smoke_verifies() {
    let m = run(256, "fattree", AlgoType::RecursiveDoubling, 3);
    assert_eq!(m.host_overall().count(), 256 * 3);
    assert!(m.switch_frames_forwarded > 0);
    // O(log p) check at scale: 256 ranks = 8 steps vs 64 ranks = 6
    let lat64 = run(64, "fattree", AlgoType::RecursiveDoubling, 3).host_overall().avg_ns();
    let lat256 = m.host_overall().avg_ns();
    assert!(lat256 > lat64);
    assert!(
        lat256 < 2.5 * lat64,
        "p=256 latency {lat256} must grow like log p over p=64's {lat64}"
    );
}
