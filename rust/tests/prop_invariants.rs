//! Property-based invariants over the whole system (quickcheck-lite
//! runner from `nfscan::prop` — the offline build has no proptest crate).
//!
//! The central invariant: for ANY (algorithm, path, p, op, dtype, message
//! size, collective flavor, arrival skew, seed), every rank's MPI_Scan
//! result equals the oracle prefix, and the simulation is bit-deterministic
//! from its seed.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::data::{Dtype, Op, Payload};
use nfscan::net::frame::{fragment, reassemble};
use nfscan::net::{Frame, FrameBody, RouteTable, Topology};
use nfscan::packet::{AlgoType, CollType};
use nfscan::prop::{choose, for_each_case, permutation, vec_i32};
use nfscan::runtime::make_engine;
use nfscan::sim::SplitMix64;

fn random_cfg(rng: &mut SplitMix64) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.algo = *choose(rng, &AlgoType::ALL);
    cfg.coll = *choose(
        rng,
        &[CollType::Scan, CollType::Scan, CollType::Exscan, CollType::Allreduce, CollType::Barrier],
    );
    if matches!(cfg.coll, CollType::Allreduce | CollType::Barrier)
        && cfg.algo == AlgoType::Sequential
    {
        cfg.algo = AlgoType::RecursiveDoubling;
    }
    cfg.p = match (cfg.algo, cfg.coll) {
        (AlgoType::Sequential, CollType::Scan | CollType::Exscan) => {
            *choose(rng, &[2usize, 3, 5, 8, 13])
        }
        _ => *choose(rng, &[2usize, 4, 8, 16]),
    };
    cfg.path = if rng.next_below(2) == 0 { ExecPath::Fpga } else { ExecPath::Sw };
    if rng.next_below(3) == 0 {
        // sometimes run on a hierarchical fabric instead of the
        // algorithm's natural direct wiring (valid at every p above)
        cfg.topology = choose(rng, &["star:4", "fattree"]).to_string();
    }
    cfg.dtype = *choose(rng, &Dtype::ALL);
    cfg.op = loop {
        let op = *choose(rng, &Op::ALL);
        if op.valid_for(cfg.dtype) {
            break op;
        }
    };
    // sizes spanning sub-element..multi-fragment
    let elems = *choose(rng, &[1usize, 3, 17, 360, 1000]);
    cfg.msg_bytes = elems * cfg.dtype.size();
    cfg.iters = 3;
    cfg.warmup = 1;
    cfg.seed = rng.next_u64();
    cfg.cost.start_jitter_ns = *choose(rng, &[0u64, 5_000, 200_000]);
    if rng.next_below(3) == 0 {
        cfg.late_rank = Some(rng.next_below(cfg.p as u64) as usize);
        cfg.late_delay_ns = rng.range(10_000, 400_000);
    }
    cfg.verify = true;
    cfg
}

#[test]
fn every_rank_matches_oracle_everywhere() {
    // verification happens inside the cluster (cfg.verify): any mismatch
    // panics with the series + rank + epoch.
    for_each_case(60, 0xA11_C0DE, |rng| {
        let cfg = random_cfg(rng);
        let compute = make_engine(EngineKind::Native, "artifacts");
        let mut cluster = Cluster::new(cfg.clone(), compute);
        cluster.run().unwrap_or_else(|e| {
            panic!("deadlock for {:?}/{}: {e}", cfg.algo, cfg.series_name())
        });
    });
}

#[test]
fn simulation_is_deterministic_from_seed() {
    for_each_case(12, 0xDE7E12, |rng| {
        let cfg = random_cfg(rng);
        let run = |cfg: ExpConfig| {
            let compute = make_engine(EngineKind::Native, "artifacts");
            let mut cluster = Cluster::new(cfg, compute);
            cluster.run().unwrap()
        };
        let a = run(cfg.clone());
        let b = run(cfg);
        assert_eq!(a.sim_ns, b.sim_ns);
        assert_eq!(a.total_frames(), b.total_frames());
        assert_eq!(a.host_overall().avg_ns(), b.host_overall().avg_ns());
        assert_eq!(a.host_overall().min_ns(), b.host_overall().min_ns());
        assert_eq!(a.nic_overall().avg_ns(), b.nic_overall().avg_ns());
    });
}

#[test]
fn scan_once_matches_oracle_for_arbitrary_payloads() {
    for_each_case(30, 0x5CA_40CE, |rng| {
        let algo = *choose(rng, &AlgoType::ALL);
        let p = *choose(rng, &[2usize, 4, 8]);
        let n = 1 + rng.next_below(64) as usize;
        let contributions: Vec<Payload> =
            (0..p).map(|_| Payload::from_i32(&vec_i32(rng, n, 50))).collect();
        let mut cfg = ExpConfig::default();
        cfg.p = p;
        cfg.algo = algo;
        cfg.path = ExecPath::Fpga;
        cfg.verify = true;
        let compute = make_engine(EngineKind::Native, "artifacts");
        let (results, _) =
            Cluster::scan_once(cfg, Rc::clone(&compute), contributions.clone()).unwrap();
        let mut acc = vec![0i64; n];
        for (rank, c) in contributions.iter().enumerate() {
            for (i, v) in c.to_i32().iter().enumerate() {
                acc[i] += *v as i64;
            }
            let got = results[rank].to_i32();
            for (i, &a) in acc.iter().enumerate() {
                assert_eq!(got[i] as i64, a, "rank {rank} elem {i} ({algo:?})");
            }
        }
    });
}

#[test]
fn fragmentation_roundtrips_any_payload() {
    for_each_case(100, 0xF4A6, |rng| {
        let n = 1 + rng.next_below(3000) as usize;
        let p = Payload::from_i32(&vec_i32(rng, n, 1000));
        let frags = fragment(&p);
        assert!(!frags.is_empty());
        // indices are dense and ascending
        for (i, (idx, total, _, _)) in frags.iter().enumerate() {
            assert_eq!(*idx as usize, i);
            assert_eq!(*total as usize, frags.len());
        }
        let whole = reassemble(&frags.iter().map(|(_, _, _, c)| c.clone()).collect::<Vec<_>>());
        assert_eq!(whole, p);
    });
}

#[test]
fn frame_wire_roundtrip_fuzz() {
    for_each_case(100, 0xF4A7E, |rng| {
        let n = rng.next_below(300) as usize;
        let msg = nfscan::net::SwMsg {
            src: rng.next_below(200) as usize,
            algo: 1 + rng.next_below(3) as u16,
            kind: nfscan::net::SwMsgKind::Data,
            epoch: rng.next_u64() as u32,
            step: rng.next_below(16) as u16,
            count: n as u32,
            frag_idx: 0,
            frag_total: 1,
            payload: Payload::from_i32(&vec_i32(rng, n, i32::MAX as i64)),
        };
        let f = Frame::new(msg.src, rng.next_below(200) as usize, FrameBody::Sw(msg.clone()));
        let back = Frame::parse(&f.serialize()).expect("roundtrip");
        match back.body {
            FrameBody::Sw(m) => {
                assert_eq!(m.src, msg.src);
                assert_eq!(m.epoch, msg.epoch);
                assert_eq!(m.payload, msg.payload);
            }
            _ => panic!("wrong body"),
        }
    });
}

#[test]
fn corrupted_frames_never_parse_as_valid() {
    // flip one random byte: the frame must either fail to parse or parse
    // into something whose payload differs (no silent corruption into a
    // "valid" identical-claim frame is possible to assert generally, but
    // header corruption must be caught by checksums/enums).
    for_each_case(60, 0xBADF, |rng| {
        let msg = nfscan::net::SwMsg {
            src: 2,
            algo: 1,
            kind: nfscan::net::SwMsgKind::Data,
            epoch: 7,
            step: 0,
            count: 4,
            frag_idx: 0,
            frag_total: 1,
            payload: Payload::from_i32(&[1, 2, 3, 4]),
        };
        let f = Frame::new(2, 5, FrameBody::Sw(msg));
        let mut bytes = f.serialize();
        // corrupt within the IP header: always detected by its checksum
        let pos = 14 + rng.next_below(20) as usize;
        let bit = 1u8 << rng.next_below(8);
        bytes[pos] ^= bit;
        assert!(
            Frame::parse(&bytes).is_none(),
            "IP header corruption at byte {pos} (bit {bit:#x}) must be detected"
        );
    });
}

#[test]
fn routing_reaches_everyone_on_all_topologies() {
    for_each_case(40, 0x707, |rng| {
        let p = *choose(rng, &[2usize, 4, 8, 16, 64]);
        let topo = match rng.next_below(5) {
            0 => Topology::chain(p),
            1 if p >= 3 => Topology::ring(p),
            2 => Topology::star(p, *choose(rng, &[2usize, 4, 8])).unwrap(),
            3 => Topology::fattree(p, Topology::fattree_arity_for(p)).unwrap(),
            _ => Topology::hypercube(p),
        };
        let routes = RouteTable::build(&topo);
        let perm = permutation(rng, p);
        for (i, &src) in perm.iter().enumerate() {
            let dst = perm[(i + 1) % p];
            if src != dst {
                let hops = routes.hops(&topo, src, dst).expect("reachable");
                assert!(
                    hops >= 1 && hops < topo.nodes(),
                    "{src}->{dst} hops {hops} on {}",
                    topo.name()
                );
            }
        }
    });
}

#[test]
fn sw_seq_pipeline_latency_beats_first_iteration() {
    // steady-state pipelining: in back-to-back sw sequential runs, the
    // minimum latency must be well under a cold full-chain traversal.
    let mut cfg = ExpConfig::default();
    cfg.algo = AlgoType::Sequential;
    cfg.path = ExecPath::Sw;
    cfg.iters = 100;
    cfg.warmup = 8;
    cfg.verify = true;
    let compute = make_engine(EngineKind::Native, "artifacts");
    let mut cluster = Cluster::new(cfg.clone(), compute);
    let m = cluster.run().unwrap();
    let cold_chain =
        (cfg.p as u64 - 1) * (cfg.cost.sw_send_overhead_ns + cfg.cost.sw_recv_overhead_ns);
    assert!(
        m.host_overall().min_ns() < cold_chain / 2,
        "pipelined min {} must beat cold chain {}",
        m.host_overall().min_ns(),
        cold_chain
    );
}
