//! Integration: the XLA runtime (AOT Pallas->HLO artifacts via PJRT)
//! against the native oracle.  Skips gracefully when `make artifacts`
//! hasn't run (unit tests must not require python).

use nfscan::data::{Dtype, Op, Payload};
use nfscan::runtime::{Compute, NativeEngine, XlaEngine};

fn xla() -> Option<XlaEngine> {
    match XlaEngine::load("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping: artifacts not built ({err})");
            None
        }
    }
}

fn i32_payload(n: usize, k: i32) -> Payload {
    Payload::from_i32(&(0..n as i32).map(|v| (v * k) % 23 - 11).collect::<Vec<_>>())
}

#[test]
fn combine_matches_native_all_ops_i32() {
    let Some(xla) = xla() else { return };
    let native = NativeEngine::new();
    for op in Op::ALL {
        for n in [1usize, 7, 2048, 2049, 6000] {
            let a = i32_payload(n, 3);
            let b = i32_payload(n, 5);
            let x = xla.combine(&a, &b, op).unwrap();
            let y = native.combine(&a, &b, op).unwrap();
            assert_eq!(x, y, "op {op:?} n {n}");
        }
    }
}

#[test]
fn combine_matches_native_floats() {
    let Some(xla) = xla() else { return };
    let native = NativeEngine::new();
    for op in [Op::Sum, Op::Prod, Op::Max, Op::Min] {
        let a =
            Payload::from_f32(&(0..3000).map(|v| (v % 13) as f32 * 0.5 - 3.0).collect::<Vec<_>>());
        let b = Payload::from_f32(&(0..3000).map(|v| (v % 7) as f32 * 0.25).collect::<Vec<_>>());
        let x = xla.combine(&a, &b, op).unwrap().to_f32();
        let y = native.combine(&a, &b, op).unwrap().to_f32();
        for (i, (p, q)) in x.iter().zip(y.iter()).enumerate() {
            assert!((p - q).abs() < 1e-6, "f32 {op:?} [{i}]: {p} vs {q}");
        }
        let a =
            Payload::from_f64(&(0..3000).map(|v| (v % 13) as f64 * 0.5 - 3.0).collect::<Vec<_>>());
        let b = Payload::from_f64(&(0..3000).map(|v| (v % 7) as f64 * 0.25).collect::<Vec<_>>());
        let x = xla.combine(&a, &b, op).unwrap().to_f64();
        let y = native.combine(&a, &b, op).unwrap().to_f64();
        for (i, (p, q)) in x.iter().zip(y.iter()).enumerate() {
            assert!((p - q).abs() < 1e-12, "f64 {op:?} [{i}]: {p} vs {q}");
        }
    }
}

#[test]
fn scan_matches_native_across_block_boundary() {
    let Some(xla) = xla() else { return };
    let native = NativeEngine::new();
    for inclusive in [true, false] {
        for n in [1usize, 100, 2048, 2049, 4096, 5000] {
            let x = i32_payload(n, 7);
            let a = xla.scan(&x, Op::Sum, inclusive).unwrap();
            let b = native.scan(&x, Op::Sum, inclusive).unwrap();
            assert_eq!(a, b, "i32 scan inclusive={inclusive} n={n}");
        }
        // f64 with tolerance (association differs across blocks)
        let x = Payload::from_f64(&(0..5000).map(|v| (v % 17) as f64 * 0.125).collect::<Vec<_>>());
        let a = xla.scan(&x, Op::Sum, inclusive).unwrap().to_f64();
        let b = native.scan(&x, Op::Sum, inclusive).unwrap().to_f64();
        for (i, (p, q)) in a.iter().zip(b.iter()).enumerate() {
            assert!((p - q).abs() < 1e-8, "f64 scan [{i}]: {p} vs {q}");
        }
    }
}

#[test]
fn derive_matches_native() {
    let Some(xla) = xla() else { return };
    let native = NativeEngine::new();
    for n in [1usize, 2048, 3000] {
        let own = i32_payload(n, 3);
        let peer = i32_payload(n, 9);
        let cum = native.combine(&peer, &own, Op::Sum).unwrap();
        assert_eq!(xla.derive(&cum, &own).unwrap(), peer, "n {n}");
    }
}

#[test]
fn scan_over_padding_is_not_polluted() {
    // padding with the op identity must not leak into real elements:
    // max with pad=i32::MIN, min with pad=i32::MAX, prod with pad=1
    let Some(xla) = xla() else { return };
    let native = NativeEngine::new();
    for op in [Op::Max, Op::Min, Op::Prod, Op::Sum] {
        let n = 2047; // one short of the block: forces a pad element
        let a = i32_payload(n, 3);
        let b = i32_payload(n, 5);
        assert_eq!(
            xla.combine(&a, &b, op).unwrap(),
            native.combine(&a, &b, op).unwrap(),
            "op {op:?}"
        );
    }
}

#[test]
fn full_cluster_on_xla_engine() {
    // the paper's experiment with every reduction routed through PJRT
    let Some(_probe) = xla() else { return };
    let mut cfg = nfscan::config::ExpConfig::default();
    cfg.engine = nfscan::config::EngineKind::Xla;
    cfg.verify = true;
    cfg.iters = 10;
    cfg.warmup = 2;
    cfg.msg_bytes = 64;
    let compute = nfscan::runtime::make_engine(cfg.engine, "artifacts");
    assert_eq!(compute.name(), "xla");
    let mut cluster = nfscan::cluster::Cluster::new(cfg, compute);
    let m = cluster.run().unwrap();
    assert_eq!(m.host_overall().count(), 8 * 10);
}
