//! Integration: the observability layer end to end.  The Perfetto
//! export round-trips through the crate's own JSON parser and its flow
//! arrows follow a retransmitted frame across the drop; turning
//! latency attribution on measures where time went without perturbing
//! a single latency sample or event.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::ExpConfig;
use nfscan::metrics::json::Json;
use nfscan::runtime::NativeEngine;
use nfscan::trace::TraceKind;

/// Default offloaded run with a deterministic first-frame drop on the
/// 0->1 link, so exactly which txn retransmits is knowable.
fn lossy_cfg() -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.p = 4;
    cfg.iters = 3;
    cfg.warmup = 1;
    cfg.set_run("drop", "0->1:1").unwrap();
    cfg.set_run("max_retries", "8").unwrap();
    cfg.validate().unwrap();
    cfg
}

#[test]
fn perfetto_export_follows_a_retransmitted_frame() {
    let cfg = lossy_cfg();
    let mut cluster = Cluster::new(cfg.clone(), Rc::new(NativeEngine::new()));
    cluster.enable_trace(65_536);
    let m = cluster.run().unwrap();
    assert!(m.retransmits > 0, "the drop schedule must force a retransmit");

    // the dropped frame's txn shows up again at its retransmit
    let dropped_txn = cluster
        .trace
        .iter()
        .find(|e| e.kind == TraceKind::Dropped)
        .expect("the dropped frame is recorded")
        .data
        .txn;
    assert_ne!(dropped_txn, 0, "reliable frames carry a txn id");
    assert!(cluster
        .trace
        .iter()
        .any(|e| e.kind == TraceKind::Retransmit && e.data.txn == dropped_txn));

    // the export is valid JSON by our own strict parser, byte-stably
    let doc = cluster.trace.chrome_trace(cfg.p);
    let text = doc.pretty();
    let back = Json::parse(&text).unwrap();
    assert_eq!(back.pretty(), text, "chrome-trace JSON round-trips");

    // flow arrows: the dropped txn reads as one start -> ... -> finish
    // chain (the drop and the retransmit are interior steps)
    let events = back.get("traceEvents").unwrap().as_arr().unwrap();
    let flows = |ph: &str| {
        events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(|v| v.as_str()) == Some(ph)
                    && e.get("id").and_then(|v| v.as_u64()) == Some(dropped_txn)
            })
            .count()
    };
    assert_eq!(flows("s"), 1, "one flow start for the dropped txn");
    assert!(flows("t") >= 1, "flow steps through the drop");
    assert_eq!(flows("f"), 1, "one flow finish for the dropped txn");
    let named = |name: &str| {
        events.iter().any(|e| {
            e.get("name").and_then(|v| v.as_str()) == Some(name)
                && e.get("args").and_then(|a| a.get("txn")).and_then(|v| v.as_u64())
                    == Some(dropped_txn)
        })
    };
    assert!(named("dropped"), "the drop instant is on the chain");
    assert!(named("retransmit"), "the retransmit instant is on the chain");
}

#[test]
fn attribution_measures_without_perturbing_the_run() {
    let mut base = ExpConfig::default();
    base.p = 4;
    base.iters = 5;
    base.warmup = 1;
    base.validate().unwrap();

    let run = |attribution: bool| {
        let mut cfg = base.clone();
        cfg.attribution = attribution;
        let mut cluster = Cluster::new(cfg, Rc::new(NativeEngine::new()));
        cluster.run().unwrap()
    };
    let off = run(false);
    let on = run(true);

    // measuring must not move anything: same samples, same schedule
    assert_eq!(off.host_overall(), on.host_overall(), "latency samples identical");
    assert_eq!(off.sim_ns, on.sim_ns, "event schedule identical");
    assert_eq!(off.total_frames(), on.total_frames());
    assert!(off.attribution.is_none(), "off by default");
    assert!(off.host_hist.is_empty(), "no histogram unless asked");

    let a = on.attribution.expect("attribution measured");
    assert_eq!(a.components_sum(), a.latency_ns, "components sum exactly to the total");
    assert!(a.wire_ns > 0, "frames crossed wires");
    assert_eq!(
        on.host_hist.count(),
        on.host_overall().count(),
        "one histogram sample per measured completion"
    );
}

#[test]
fn attribution_sums_exactly_on_a_lossy_run() {
    let mut cfg = lossy_cfg();
    cfg.attribution = true;
    let mut cluster = Cluster::new(cfg, Rc::new(NativeEngine::new()));
    let m = cluster.run().unwrap();
    assert!(m.retransmits > 0);
    let a = m.attribution.unwrap();
    assert_eq!(a.components_sum(), a.latency_ns, "sum identity survives recovery");
}
