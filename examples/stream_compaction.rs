//! Application example: distributed stream compaction — the classic
//! prefix-sum use case (Blelloch [8], which the paper cites as the
//! motivation for MPI_Scan).
//!
//!     cargo run --release --example stream_compaction
//!
//! Each rank holds a shard of a distributed array and keeps only the
//! elements matching a predicate.  The global output offsets come from an
//! offloaded **MPI_Exscan** over per-rank survivor counts — exactly the
//! pattern radix sort, filtering and load balancing use.  The local
//! prefix positions come from the runtime's block-scan (the L1 Pallas
//! kernel when artifacts are present).

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::data::{Op, Payload};
use nfscan::packet::{AlgoType, CollType};
use nfscan::runtime::make_engine;
use nfscan::sim::SplitMix64;

fn main() -> anyhow::Result<()> {
    const P: usize = 8;
    const SHARD: usize = 1000;
    let keep = |v: i32| v % 3 == 0;

    // each rank's shard of the distributed array
    let mut rng = SplitMix64::new(2014);
    let shards: Vec<Vec<i32>> =
        (0..P).map(|_| (0..SHARD).map(|_| rng.range_i64(0, 999) as i32).collect()).collect();

    let compute = make_engine(EngineKind::Xla, "artifacts");
    println!("compute engine: {}\n", compute.name());

    // ---- step 1: local survivor count per rank ----
    let counts: Vec<i32> =
        shards.iter().map(|s| s.iter().filter(|&&v| keep(v)).count() as i32).collect();
    println!("per-rank survivor counts: {counts:?}");

    // ---- step 2: offloaded MPI_Exscan over the counts -> global offsets
    let mut cfg = ExpConfig::default();
    cfg.p = P;
    cfg.coll = CollType::Exscan;
    cfg.algo = AlgoType::BinomialTree;
    cfg.path = ExecPath::Fpga;
    cfg.verify = true;
    let contributions: Vec<Payload> = counts.iter().map(|&c| Payload::from_i32(&[c])).collect();
    let (offsets, metrics) = Cluster::scan_once(cfg, Rc::clone(&compute), contributions)?;
    let offsets: Vec<i32> = offsets.iter().map(|p| p.to_i32()[0]).collect();
    println!("global output offsets   : {offsets:?}");
    println!(
        "exscan latency          : {:.2} us end-to-end, {:.2} us on-NIC\n",
        metrics.host_overall().avg_us(),
        metrics.nic_overall().avg_us()
    );

    // ---- step 3: local compaction into the global output ----
    let total: usize = counts.iter().map(|&c| c as usize).sum();
    let mut output = vec![0i32; total];
    for (rank, shard) in shards.iter().enumerate() {
        // local positions via the runtime's exclusive block scan (the L1
        // Pallas kernel path when artifacts are loaded)
        let flags: Vec<i32> = shard.iter().map(|&v| keep(v) as i32).collect();
        let local_pos = compute.scan(&Payload::from_i32(&flags), Op::Sum, false)?.to_i32();
        for (i, &v) in shard.iter().enumerate() {
            if keep(v) {
                output[offsets[rank] as usize + local_pos[i] as usize] = v;
            }
        }
    }

    // verify against the straightforward sequential compaction
    let want: Vec<i32> =
        shards.iter().flatten().copied().filter(|&v| keep(v)).collect();
    anyhow::ensure!(output == want, "compaction mismatch");
    println!(
        "compacted {} of {} elements across {P} ranks — matches sequential reference",
        total,
        P * SHARD
    );
    println!("stream_compaction OK");
    Ok(())
}
