//! The Fig. 2/3 scenario: a late-arriving rank and the recursive-doubling
//! multicast + inverse-subtract optimization (paper SSIII-C).
//!
//!     cargo run --release --example late_rank
//!
//! Rank 1 calls MPI_Scan 500 us after everyone else (its partner's step-0
//! data is already buffered on its NetFPGA when the request arrives).
//! With the optimization the card emits ONE tagged cumulative multicast
//! instead of two generated packets; rank 0 reconstructs rank 1's raw
//! block by subtracting its cached contribution.  The example runs both
//! variants and reports the multicast count and latency difference.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::packet::AlgoType;
use nfscan::runtime::make_engine;

fn run(multicast_opt: bool) -> anyhow::Result<nfscan::metrics::RunMetrics> {
    let mut cfg = ExpConfig::default();
    cfg.p = 4;
    cfg.algo = AlgoType::RecursiveDoubling;
    cfg.path = ExecPath::Fpga;
    cfg.verify = true;
    cfg.iters = 200;
    cfg.warmup = 8;
    cfg.late_rank = Some(1);
    cfg.late_delay_ns = 500_000;
    cfg.cost.start_jitter_ns = 0;
    cfg.multicast_opt = multicast_opt;
    let compute = make_engine(EngineKind::Native, "artifacts");
    let mut cluster = Cluster::new(cfg, Rc::clone(&compute));
    Ok(cluster.run()?)
}

fn main() -> anyhow::Result<()> {
    println!("late-rank scenario: 4 ranks, rank 1 arrives 500 us late\n");
    let with = run(true)?;
    let without = run(false)?;

    println!("                         with opt    without opt");
    println!(
        "multicasts taken      : {:>9}    {:>11}",
        with.multicasts, without.multicasts
    );
    println!(
        "frames on the wire    : {:>9}    {:>11}",
        with.total_frames(),
        without.total_frames()
    );
    println!(
        "avg latency (us)      : {:>9.2}    {:>11.2}",
        with.host_overall().avg_us(),
        without.host_overall().avg_us()
    );
    println!(
        "rank-1 avg latency    : {:>9.2}    {:>11.2}",
        with.host_latency[1].avg_us(),
        without.host_latency[1].avg_us()
    );

    anyhow::ensure!(with.multicasts > 0, "optimization must trigger");
    anyhow::ensure!(without.multicasts == 0);
    anyhow::ensure!(
        with.host_overall().avg_ns() < without.host_overall().avg_ns(),
        "one packet generation saved per multicast must show up"
    );
    println!(
        "\nlate_rank OK — optimization taken {} times, all results oracle-verified",
        with.multicasts
    );
    Ok(())
}
