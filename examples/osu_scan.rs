//! End-to-end driver: the paper's full evaluation on the simulated
//! testbed, with payload reductions executed through the compiled HLO
//! artifacts via PJRT (run `make artifacts` first; falls back to native
//! with a warning otherwise).
//!
//!     cargo run --release --example osu_scan [iters]
//!
//! Regenerates every table/figure of the paper's SSIV — Fig. 4 (average
//! latency), Fig. 5 (minimum latency), Fig. 6 (average on-NIC latency),
//! Fig. 7 (minimum on-NIC latency) — over the OSU size ladder on 8 nodes,
//! with result verification against the oracle ON for every cell, then
//! checks the paper's qualitative claims hold.  Output is what
//! EXPERIMENTS.md records.

use nfscan::bench::{self, Metric};
use nfscan::config::{EngineKind, ExpConfig};
use nfscan::runtime::make_engine;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::args().nth(1).map(|v| v.parse().unwrap()).unwrap_or(300);
    let mut base: ExpConfig = bench::figure_base(iters);
    base.engine = EngineKind::Xla;
    base.verify = true; // every scan checked against the oracle
    let compute = make_engine(base.engine, "artifacts");

    println!("== nf-scan end-to-end evaluation ==");
    println!(
        "testbed: {} simulated nodes | engine: {} | {} measured iterations per cell\n",
        base.p,
        compute.name(),
        iters
    );

    let sizes = bench::OSU_SIZES;
    let paper = bench::run_sweep(&base, &bench::paper_series(), sizes, compute.clone());
    let nf = bench::run_sweep(&base, &bench::nf_series(), sizes, compute);

    println!("Fig. 4 — average MPI_Scan latency (us), 8 nodes");
    print!("{}", paper.table(Metric::HostAvg).render());
    println!("\nFig. 5 — minimum MPI_Scan latency (us), 8 nodes");
    print!("{}", paper.table(Metric::HostMin).render());
    println!("\nFig. 6 — average on-NIC latency after offload (us)");
    print!("{}", nf.table(Metric::NicAvg).render());
    println!("\nFig. 7 — minimum on-NIC latency after offload (us)");
    print!("{}", nf.table(Metric::NicMin).render());

    // ---- the paper's qualitative claims, asserted ----
    // The paper's offload packets are single UDP datagrams; its figures
    // live in the <= few-KB regime.  Beyond ~4KB wire serialization of
    // the fragments dominates BOTH paths and the offload advantage
    // legitimately collapses — so claims are asserted where the paper
    // measured them (single-to-few-fragment sizes).
    // series order: 0 sw_seq, 1 sw_rd, 2 NF_seq, 3 NF_rd, 4 NF_binomial
    let avg = |j: usize, i: usize| paper.cells[j][i].0.avg_ns();
    let min = |j: usize, i: usize| paper.cells[j][i].0.min_ns();
    let nic_avg = |j: usize, i: usize| nf.cells[j][i].1.avg_ns();
    let mut checks = Vec::new();
    for i in 0..sizes.len() {
        checks.push(("sw_seq has the lowest average latency", avg(0, i) < avg(1, i)));
        let global_min = min(0, i) <= min(1, i) && min(0, i) <= min(2, i);
        checks.push(("sw_seq min is the global min", global_min));
        if sizes[i] <= 4096 {
            checks.push(("NF_rd beats sw_rd significantly (paper regime)", avg(3, i) < avg(1, i)));
        }
        if sizes[i] <= 1024 {
            // crossing-dominated regime: the NIC does its work in a small
            // fraction of what the host observes
            for j in 0..3 {
                checks.push((
                    "on-NIC latency sits far below end-to-end (small messages)",
                    nic_avg(j, i) * 2.0 < nf.cells[j][i].0.avg_ns(),
                ));
            }
        }
    }
    let failed: Vec<_> = checks.iter().filter(|(_, ok)| !ok).collect();
    println!("\nqualitative checks: {}/{} hold", checks.len() - failed.len(), checks.len());
    for (what, _) in &failed {
        println!("  FAILED: {what}");
    }
    anyhow::ensure!(failed.is_empty(), "paper-shape checks failed");
    println!("osu_scan OK — all scans oracle-verified, all paper-shape checks hold");
    Ok(())
}
