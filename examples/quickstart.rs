//! Quickstart: one offloaded MPI_Scan on a simulated 8-node NetFPGA
//! cluster, through the public API.
//!
//!     cargo run --release --example quickstart
//!
//! Each rank contributes a small integer vector; the NetFPGA network runs
//! the recursive-doubling scan state machines and every rank receives its
//! prefix sum, timed both end-to-end and on-NIC.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::data::Payload;
use nfscan::packet::AlgoType;
use nfscan::runtime::make_engine;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExpConfig::default();
    cfg.p = 8;
    cfg.algo = AlgoType::RecursiveDoubling;
    cfg.path = ExecPath::Fpga;
    cfg.verify = true;
    cfg.engine = EngineKind::Xla; // falls back to native if artifacts absent

    let compute = make_engine(cfg.engine, "artifacts");
    println!("compute engine: {}", compute.name());

    // every rank contributes [rank+1, 10*(rank+1), 100]
    let contributions: Vec<Payload> = (0..cfg.p)
        .map(|r| Payload::from_i32(&[r as i32 + 1, 10 * (r as i32 + 1), 100]))
        .collect();

    let (results, metrics) = Cluster::scan_once(cfg, Rc::clone(&compute), contributions)?;

    println!("\nrank | MPI_Scan result (inclusive prefix sums)");
    println!("-----+----------------------------------------");
    for (rank, result) in results.iter().enumerate() {
        println!("  {rank}  | {:?}", result.to_i32());
    }
    let expect: i32 = (1..=8).sum();
    assert_eq!(results[7].to_i32()[0], expect, "rank 7 sums 1..=8");

    let host_avg = metrics.host_overall().avg_us();
    let nic_avg = metrics.nic_overall().avg_us();
    println!("\nend-to-end latency : {host_avg:.2} us (avg over ranks)");
    println!("on-NIC latency     : {nic_avg:.2} us (offload->release timestamps)");
    println!("frames on the wire : {}", metrics.total_frames());
    println!("\nquickstart OK");
    Ok(())
}
