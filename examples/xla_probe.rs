// temporary probe: output buffer structure + fast readback path
use nfscan::runtime::XlaEngine;
use nfscan::data::{Op, Dtype, Payload};
fn main() -> anyhow::Result<()> {
    let e = XlaEngine::load("artifacts")?;
    e.probe_output_structure()?;
    Ok(())
}
